#!/usr/bin/env bash
# Minimal CI: tier-1 suite on CPU with Pallas kernels in interpret mode,
# plus example smoke runs so API breakage in examples fails CI.
#
# Off-TPU every pallas_call auto-selects interpret=True (see
# repro.kernels.interpret_default), so this exercises the real kernel
# dataflow — including the fused exit-gate chain — without hardware.
#
#   ./scripts/ci.sh            # whole tier-1 suite + example smoke
#   ./scripts/ci.sh tests/test_exit_gate.py   # one file (skips examples)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
# hypothesis-based suites importorskip when dev deps are absent
python -m pip install -q -r requirements-dev.txt 2>/dev/null || true

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# example smoke (tiny configs, interpret mode): quickstart drives
# Engine/DecodeSession directly, serve_specee trains a minimal bundle and
# serves through the continuous-batching engine. Only on full-suite runs.
if [ "$#" -eq 0 ]; then
  echo "[ci] examples/quickstart.py (smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python examples/quickstart.py --new-tokens 3
  echo "[ci] examples/serve_specee.py --ci (smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python examples/serve_specee.py --ci
  # paged-cache serving smoke: exercises the KVCacheManager page-table path
  # and the chunked-prefill scheduler on every run (page leak + budget
  # asserts live behind --ci)
  echo "[ci] launch/serve.py --ci --page-size 16 (paged smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --page-size 16
  # megatick serving smoke: device-resident K-tick decode + async pipeline.
  # --ci with --megatick > 1 asserts completion, zero page leak, and token
  # parity against a megatick=1 reference run internally.
  echo "[ci] launch/serve.py --ci --megatick 8 (megatick smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --megatick 8
  # quantized serving smoke: weight-only int8 bundle (repro.quant) with
  # dequant fused into the decode kernels; --ci asserts completion, zero
  # page leak, and token parity against a quantized megatick=1 reference
  echo "[ci] launch/serve.py --ci --quant int8 --megatick 4 (quant smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --quant int8 --megatick 4

  # kill/restore smoke: SIGTERM a serving run mid-decode (the engine drains
  # the in-flight megatick, saves a step-atomic checkpoint, exits 17), then
  # restart with --restore — --ci asserts the resumed run completes every
  # request token-identical to a fault-free reference with zero page leak.
  # If the run wins the race and finishes before the signal (rc 0), the
  # restore run finds an empty checkpoint dir and serves fresh — the same
  # asserts still hold.
  echo "[ci] launch/serve.py kill/restore smoke (SIGTERM mid-decode)"
  CKPT_DIR="$(mktemp -d)"
  trap 'rm -rf "$CKPT_DIR"' EXIT
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --megatick 4 \
      --checkpoint-dir "$CKPT_DIR" >/dev/null 2>&1 &
  SERVE_PID=$!
  sleep 8
  kill -TERM "$SERVE_PID" 2>/dev/null || true
  RC=0; wait "$SERVE_PID" || RC=$?
  if [ "$RC" -ne 17 ] && [ "$RC" -ne 0 ]; then
    echo "[ci] kill/restore smoke: serve exited rc=$RC (want 17 or 0)" >&2
    exit 1
  fi
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --megatick 4 \
      --checkpoint-dir "$CKPT_DIR" --restore

  # fault-injection sweep: each named site fires once (deterministic
  # schedule); --ci asserts every request still completes token-identical
  # to the fault-free reference and the pool leaks nothing. The sigterm
  # site preempts + restores in-process.
  for SITE in dispatch finish_timeout nan_logits pool_exhausted sigterm; do
    echo "[ci] launch/serve.py --ci --inject $SITE (fault-injection sweep)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m repro.launch.serve --ci --megatick 4 --inject "$SITE"
  done

  # elastic remesh smoke (DESIGN.md §10): lose a device out of a TP=2 mesh
  # mid-decode — the engine must remesh to TP=1 in place (not die), finish
  # every request token-identical to the unsharded fault-free reference,
  # and export a non-empty JSONL fault trail.
  echo "[ci] launch/serve.py --ci --inject device_lost --mesh 1,2 (remesh smoke)"
  FLOG="$(mktemp)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --megatick 4 --mesh 1,2 \
      --inject device_lost --fault-log "$FLOG"
  if ! grep -q '"action": "remesh"' "$FLOG"; then
    echo "[ci] remesh smoke: no remesh event in fault log $FLOG" >&2
    exit 1
  fi
  rm -f "$FLOG"

  # sharded serving smoke (DESIGN.md §9): tensor-parallel megatick on forced
  # host devices — --ci asserts token parity against an unsharded reference
  # run in the same process; then a 2-replica data-parallel pool whose
  # outputs must match a single-engine run.
  echo "[ci] launch/serve.py --ci --mesh 1,2 --megatick 4 (TP smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --mesh 1,2 --megatick 4
  echo "[ci] launch/serve.py --ci --replicas 2 (replica-pool smoke)"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --ci --replicas 2

  # serving perf gate (ROADMAP item 5): re-measure the core serving
  # variants and fail on a >20% decode_tok_s regression vs the committed
  # BENCH_serving.json rows (skips gracefully when rows are missing or
  # recorded on a different backend).
  echo "[ci] bench_serving --gate (decode_tok_s regression gate)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --gate

  # exit-gate perf gate (ROADMAP item 5): re-measure the fused gate and the
  # quantized streaming verify against the committed BENCH_exit_gate.json
  # row groups; quant_pareto quality (match_vs_dense_fp32 == 1.0) is
  # checked statically. The interpret-mode Pallas column is never re-timed.
  echo "[ci] bench_predictor --gate (exit-gate regression gate)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_predictor --gate
fi
