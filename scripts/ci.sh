#!/usr/bin/env bash
# Minimal CI: tier-1 suite on CPU with Pallas kernels in interpret mode.
#
# Off-TPU every pallas_call auto-selects interpret=True (see
# repro.kernels.interpret_default), so this exercises the real kernel
# dataflow — including the fused exit-gate chain — without hardware.
#
#   ./scripts/ci.sh            # whole tier-1 suite
#   ./scripts/ci.sh tests/test_exit_gate.py   # one file
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
# hypothesis-based suites importorskip when dev deps are absent
python -m pip install -q -r requirements-dev.txt 2>/dev/null || true

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
