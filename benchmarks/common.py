"""Shared benchmark harness: a smoke-scale Llama2-7B with a TRAINED draft and
TRAINED predictors — the full SpecEE pipeline end-to-end on CPU.

``get_bundle()`` memoizes the trained system so every benchmark reuses it.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import draft_training, engine as eng, predictor_training as pt
from repro.core import scheduler as sched_lib
from repro.data import DataPipeline
from repro.models.model import Model, build_model
from repro.train import TrainLoop


@dataclass
class Bundle:
    run: Any
    model: Model
    params: Any
    sw: eng.SpecEEWeights
    draft_metrics: Dict[str, float]
    predictor_metrics: Dict[str, float]
    offline_counts: np.ndarray


_BUNDLE: Optional[Bundle] = None


def merge_bench_json(path: str, key: str, rows: list) -> None:
    """Read-modify-write one named row-group of a benchmark JSON artifact.

    The artifact is ``{"<group>": [row, ...], ...}`` so independent benches
    (gate A/B in bench_predictor, quant Pareto in bench_ablation) can each
    refresh their own rows without clobbering the others. A legacy top-level
    list (the pre-row-group BENCH_exit_gate.json shape) is adopted as the
    ``gate_ab`` group.
    """
    data: Dict[str, Any] = {}
    try:
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, list):
            data = {"gate_ab": data}
    except (OSError, ValueError):
        data = {}
    data[key] = rows
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def token_batches(run, n: int, B: int = 4, S: int = 32, seed: int = 0):
    pipe = DataPipeline(run.model, B, S, seed=seed)
    return [jnp.asarray(pipe.next()["tokens"]) for _ in range(n)]


def get_bundle(arch: str = "llama2-7b", train_steps: int = 30,
               draft_steps: int = 250, pred_steps: int = 300,
               layers: int = 12) -> Bundle:
    global _BUNDLE
    if _BUNDLE is not None:
        return _BUNDLE
    run = get_config(arch).smoke()
    # deepen the smoke stack: exit dynamics need headroom (the paper's home
    # regime is 32 layers; 12 keeps CPU benches fast but non-trivial)
    run = dataclasses.replace(
        run, model=dataclasses.replace(run.model, num_layers=layers))
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    # 1. briefly train the TARGET so hidden dynamics are non-degenerate
    loop = TrainLoop(model, run, params)
    loop.run_steps(train_steps)
    params = loop.params
    # 2. train the DLM against the frozen target (paper §7.4.3)
    batches = token_batches(run, 8)
    draft, dmetrics = draft_training.train_draft(
        model, params, batches, jax.random.PRNGKey(1), steps=draft_steps)
    # 3. collect features + train predictors (paper §7.4.4)
    data = pt.collect_dataset(model, params, draft, batches[:4])
    predictors, pmetrics = pt.train_predictors(
        run.specee, data, jax.random.PRNGKey(2), steps=pred_steps)
    sw = eng.SpecEEWeights(
        draft=draft, predictors=predictors,
        offline_mask=jnp.ones((model.num_exit_points,), bool))
    # 4. offline exit statistics -> T2 offline schedule (paper §5.3)
    counts = pt.offline_exit_counts(model, params, sw, batches[:1],
                                    max_new=12)
    offline = sched_lib.offline_mask_from_counts(
        jnp.asarray(counts[:-1], jnp.float32), run.specee)
    sw = sw._replace(offline_mask=offline)
    _BUNDLE = Bundle(run=run, model=model, params=params, sw=sw,
                     draft_metrics=dmetrics, predictor_metrics=pmetrics,
                     offline_counts=counts)
    return _BUNDLE


def decode_run(bundle: Bundle, mode: str, prompts: jnp.ndarray,
               new_tokens: int = 24, threshold: Optional[float] = None,
               quant=None) -> Dict[str, Any]:
    """Greedy-decode ``new_tokens`` for each prompt row through the unified
    decode API (strategy step = the exact computation the serving engine
    jits per tick).

    mode: "dense" | "specee" | "specee_t1" (no scheduling).
    quant: None | "int8" | "int4" — weight-only compression (repro.quant);
    prefill runs on the dequantized view, decode on the fused int kernels
    (the same split the Engine makes).
    Returns tokens, wall time, avg units executed, exit histogram."""
    import dataclasses

    from repro.api import DenseStrategy, SpecEEStrategy
    run, m, params, sw = bundle.run, bundle.model, bundle.params, bundle.sw
    if mode == "specee_t1":
        run = dataclasses.replace(
            run, specee=dataclasses.replace(run.specee,
                                            schedule_enabled=False))
        m = build_model(run, m.flags)
    strat = (DenseStrategy() if mode == "dense"
             else SpecEEStrategy(threshold=threshold))
    qw = None
    pparams, psw = params, sw
    if quant is not None:
        from repro import quant as quant_lib
        qw = quant_lib.quantize_params(params, sw,
                                       quant_lib.QuantSpec.resolve(quant))
        pparams, psw = quant_lib.dequantized_reference(params, sw, qw)
    B, T = prompts.shape
    max_seq = T + new_tokens + 2
    first, st = strat.init_state(m, pparams, psw, {"tokens": prompts},
                                 max_seq)
    step = jax.jit(lambda p, s, stt, q: strat.step(m, p, s, stt, qw=q))
    # warmup (compile)
    step(params, sw, st, qw)
    toks, units, exits = [first], [], []
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        res, st = step(params, sw, st, qw)
        toks.append(res.tokens[:, 0])
        units.append(res.units_run)
        exits.append(res.exit_layer)
    jax.block_until_ready(toks[-1])
    dt = time.perf_counter() - t0
    units = np.asarray(jax.device_get(units))
    exits = np.asarray(jax.device_get(exits))
    return {
        "tokens": np.asarray(jnp.stack(toks, 1)),
        "seconds": dt,
        "tok_per_s": B * new_tokens / dt,
        "avg_units": float(np.mean(units)),
        "exit_points": exits,
        "avg_exit": float(np.mean(np.minimum(exits, m.num_exit_points))),
    }


class Timer:
    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")
