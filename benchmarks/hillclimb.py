"""§Perf hillclimb driver: lower each selected cell under baseline and
candidate-optimization flags, and report the roofline-term deltas.

    REPRO_DRYRUN_DEVICES=256 PYTHONPATH=src python -m benchmarks.hillclimb \
        --cell deepseek-decode --out artifacts/hillclimb_deepseek.json

Cells and candidate ladders are defined in CELLS below; every variant is a
full ``.lower().compile()`` against the production mesh (same artifact class
as the dry-run), so before/after numbers are measured, not estimated.

Verify-kernel vocab-tile sweep (ROADMAP: block_v=512 was a guess):

    PYTHONPATH=src python -m benchmarks.hillclimb --gate-blocks

times the streaming argmax-verify + top-k-verify pair across
``tuning.BLOCK_V_CANDIDATES`` per (D, V) shape (interleaved min-timing, the
same noise-symmetric harness as bench_predictor) and caches the winners in
``src/repro/configs/gate_blocks.json``, keyed by backend — the table
``exit_gate.ops`` consults whenever a caller leaves ``block_v`` unset. The
top-k kernel shares the argmax kernel's tiling knobs, so one sweep scores
their combined runtime.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.models.model import ModelFlags


def base_flags(kind: str, d_model: int, multi_pod: bool = False,
               **over) -> ModelFlags:
    kw = dict(
        remat="full" if kind == "train" else "none",
        act_batch_axes=("pod", "data") if multi_pod else "data",
        act_batch_extent=32 if multi_pod else 16,
        chunk_size=256 if d_model >= 8192 else 512,
        ce_chunk=256 if d_model >= 8192 else 512)
    kw.update(over)
    return ModelFlags(**kw)


# cell id -> (arch, shape, [(variant_name, kind, flag_overrides, extra)])
CELLS: Dict[str, Tuple[str, str, List]] = {
    "deepseek-decode": ("deepseek-7b", "decode_32k", [
        ("baseline_dense", dict(dense_decode=True), {}),
        ("specee_paper", dict(), {}),                       # paper-faithful
        ("specee_int8kv", dict(), {"kv_quant": True}),      # beyond-paper
    ]),
    "qwen3-train": ("qwen3-moe-235b-a22b", "train_4k", [
        ("baseline", dict(), {}),
        ("ep_int8_dispatch", dict(), {"moe_ep_quant": True}),
        ("ep_int8_bf16reduce", dict(), {"moe_ep_quant": True,
                                        "moe_bf16_reduce": True}),
        ("all_levers_seqshard", dict(), {"moe_ep_quant": True,
                                         "moe_bf16_reduce": True,
                                         "act_seq_shard": True}),
        ("ep_int8_pinfull", dict(), {"moe_ep_quant": True,
                                     "act_pin_full": True}),
    ]),
    "commandr-prefill": ("command-r-plus-104b", "prefill_32k", [
        ("baseline", dict(), {}),
        ("attn_prune", dict(), {"attn_prune": True}),
        ("seq_shard", dict(), {"act_seq_shard": True}),
        ("pin_full", dict(), {"act_pin_full": True}),
        ("pin_full_bf16ar", dict(), {"act_pin_full": True,
                                     "matmul_bf16_reduce": True}),
        ("best_combo", dict(), {"act_pin_full": True,
                                "matmul_bf16_reduce": True,
                                "attn_prune": True}),
    ]),
}


def run_variants(cell_id: str, multi_pod: bool = False) -> List[Dict[str, Any]]:
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import roofline_terms
    arch, shape, variants = CELLS[cell_id]
    d_model = get_config(arch).model.d_model
    kind = "train" if shape.startswith("train") else (
        "decode" if "decode" in shape or shape.startswith("long") else
        "prefill")
    out = []
    for name, runkw, flagkw in variants:
        flags = base_flags(kind, d_model, multi_pod, **flagkw)
        print(f"=== {cell_id} / {name} ===", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod, flags=flags, **runkw)
            rec["variant"] = name
            rec["roofline"] = roofline_terms(rec)
            c = rec.get("collectives_exact", {})
            print(json.dumps({
                "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0)
                / 2**30,
                "args_gb": rec.get("analytic_arg_bytes_per_device", 0) / 2**30,
                "collective_gb": c.get("total_bytes", 0) / 2**30,
                "compile_s": rec.get("compile_s"),
            }), flush=True)
        except Exception as e:
            rec = {"variant": name, "arch": arch, "shape": shape,
                   "error": repr(e)}
            print("FAILED:", repr(e), flush=True)
        out.append(rec)
    return out


# ---------------------------------------------------------------------------
# verify-kernel vocab-tile sweep
# ---------------------------------------------------------------------------
# (B, D, V): gate smoke scale, 7B-ish and 70B-ish decode shapes, a
# large-vocab frontier shape — the verify kernels see (B·N) rows in tree
# mode, so B stays modest
GATE_BLOCK_SHAPES = [(8, 128, 512), (8, 1024, 16000), (8, 2048, 32000),
                     (8, 4096, 128256)]


def sweep_gate_blocks(rounds: int = 8, iters: int = 5,
                      write_table: bool = True,
                      quant: bool = True) -> Dict[str, int]:
    """Sweep ``block_v`` for the streaming verify pair per (D, V).

    Times the impl the platform actually streams with ("kernel" on TPU,
    "xla" scan off-TPU — "ref" ignores the knob), interleaving candidates
    round-robin and keeping per-candidate minimums so shared-machine noise
    hits all candidates symmetrically. Scores argmax + top-k combined and
    merges the winners into repro/configs/gate_blocks.json under the
    current backend's key. With ``quant`` the int8/int4 verify variants are
    swept too (keys carry an ``@q8``/``@q4`` suffix — the int tiles shift
    the VMEM-residency trade-off, so their winners are cached separately).
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import on_tpu
    from repro.kernels.exit_gate import ops as gate_ops
    from repro.kernels.exit_gate import tuning
    from repro.quant import quantize_tensor

    impl = "kernel" if on_tpu() else "xla"
    k = 4
    best: Dict[str, int] = {}
    for B, D, V in GATE_BLOCK_SHAPES:
        hn = jax.random.normal(jax.random.PRNGKey(0), (B, D))
        lm_w = jax.random.normal(jax.random.PRNGKey(1), (D, V)) * 0.05
        variants = [("", lm_w)]
        if quant:
            variants += [(f"@q{bits}", quantize_tensor(lm_w, bits))
                         for bits in (8, 4)]
        cands = [bv for bv in tuning.BLOCK_V_CANDIDATES if bv <= max(V, 128)]
        for sfx, w in variants:
            fns = {}
            for bv in cands:
                fns[bv] = (
                    jax.jit(lambda h, w, bv=bv: gate_ops.verify_argmax(
                        h, w, impl=impl, block_v=bv)),
                    jax.jit(lambda h, w, bv=bv: gate_ops.verify_topk(
                        h, w, k, impl=impl, block_v=bv)))
                for f in fns[bv]:
                    jax.block_until_ready(f(hn, w))          # compile
            t_best = {bv: float("inf") for bv in cands}
            for _ in range(rounds):
                for bv in cands:
                    t0 = time.perf_counter()
                    for _ in range(iters):
                        out_a = fns[bv][0](hn, w)
                        out_t = fns[bv][1](hn, w)
                    jax.block_until_ready((out_a, out_t))
                    t_best[bv] = min(t_best[bv],
                                     (time.perf_counter() - t0) / iters)
            win = min(t_best, key=t_best.get)
            best[f"{D}x{V}{sfx}"] = win
            print(f"[gate-blocks] B={B} D={D} V={V}{sfx}: block_v={win} "
                  + " ".join(f"{bv}:{t_best[bv]*1e6:.0f}us" for bv in cands))
    if write_table:
        backend = jax.default_backend()
        table = dict(tuning._table())
        table[backend] = {**table.get(backend, {}), **best}
        os.makedirs(os.path.dirname(tuning.TABLE_PATH), exist_ok=True)
        with open(tuning.TABLE_PATH, "w") as f:
            json.dump(table, f, indent=1, sort_keys=True)
        tuning.reload_table()
        print(f"[gate-blocks] wrote {tuning.TABLE_PATH} ({backend})")
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--gate-blocks", action="store_true",
                    help="sweep verify-kernel block_v per (D, V) and cache "
                         "the winners in repro/configs/gate_blocks.json")
    ap.add_argument("--no-write", action="store_true",
                    help="with --gate-blocks: report only, don't rewrite "
                         "the cached table")
    args = ap.parse_args()
    if args.gate_blocks:
        sweep_gate_blocks(write_table=not args.no_write)
        return
    if args.cell is None:
        ap.error("one of --cell or --gate-blocks is required")
    recs = run_variants(args.cell, args.multi_pod)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1, default=str)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
