"""§Perf hillclimb driver: lower each selected cell under baseline and
candidate-optimization flags, and report the roofline-term deltas.

    REPRO_DRYRUN_DEVICES=256 PYTHONPATH=src python -m benchmarks.hillclimb \
        --cell deepseek-decode --out artifacts/hillclimb_deepseek.json

Cells and candidate ladders are defined in CELLS below; every variant is a
full ``.lower().compile()`` against the production mesh (same artifact class
as the dry-run), so before/after numbers are measured, not estimated.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.models.model import ModelFlags


def base_flags(kind: str, d_model: int, multi_pod: bool = False,
               **over) -> ModelFlags:
    kw = dict(
        remat="full" if kind == "train" else "none",
        act_batch_axes=("pod", "data") if multi_pod else "data",
        act_batch_extent=32 if multi_pod else 16,
        chunk_size=256 if d_model >= 8192 else 512,
        ce_chunk=256 if d_model >= 8192 else 512)
    kw.update(over)
    return ModelFlags(**kw)


# cell id -> (arch, shape, [(variant_name, kind, flag_overrides, extra)])
CELLS: Dict[str, Tuple[str, str, List]] = {
    "deepseek-decode": ("deepseek-7b", "decode_32k", [
        ("baseline_dense", dict(dense_decode=True), {}),
        ("specee_paper", dict(), {}),                       # paper-faithful
        ("specee_int8kv", dict(), {"kv_quant": True}),      # beyond-paper
    ]),
    "qwen3-train": ("qwen3-moe-235b-a22b", "train_4k", [
        ("baseline", dict(), {}),
        ("ep_int8_dispatch", dict(), {"moe_ep_quant": True}),
        ("ep_int8_bf16reduce", dict(), {"moe_ep_quant": True,
                                        "moe_bf16_reduce": True}),
        ("all_levers_seqshard", dict(), {"moe_ep_quant": True,
                                         "moe_bf16_reduce": True,
                                         "act_seq_shard": True}),
        ("ep_int8_pinfull", dict(), {"moe_ep_quant": True,
                                     "act_pin_full": True}),
    ]),
    "commandr-prefill": ("command-r-plus-104b", "prefill_32k", [
        ("baseline", dict(), {}),
        ("attn_prune", dict(), {"attn_prune": True}),
        ("seq_shard", dict(), {"act_seq_shard": True}),
        ("pin_full", dict(), {"act_pin_full": True}),
        ("pin_full_bf16ar", dict(), {"act_pin_full": True,
                                     "matmul_bf16_reduce": True}),
        ("best_combo", dict(), {"act_pin_full": True,
                                "matmul_bf16_reduce": True,
                                "attn_prune": True}),
    ]),
}


def run_variants(cell_id: str, multi_pod: bool = False) -> List[Dict[str, Any]]:
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import roofline_terms
    arch, shape, variants = CELLS[cell_id]
    d_model = get_config(arch).model.d_model
    kind = "train" if shape.startswith("train") else (
        "decode" if "decode" in shape or shape.startswith("long") else
        "prefill")
    out = []
    for name, runkw, flagkw in variants:
        flags = base_flags(kind, d_model, multi_pod, **flagkw)
        print(f"=== {cell_id} / {name} ===", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod, flags=flags, **runkw)
            rec["variant"] = name
            rec["roofline"] = roofline_terms(rec)
            c = rec.get("collectives_exact", {})
            print(json.dumps({
                "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0)
                / 2**30,
                "args_gb": rec.get("analytic_arg_bytes_per_device", 0) / 2**30,
                "collective_gb": c.get("total_bytes", 0) / 2**30,
                "compile_s": rec.get("compile_s"),
            }), flush=True)
        except Exception as e:
            rec = {"variant": name, "arch": arch, "shape": shape,
                   "error": repr(e)}
            print("FAILED:", repr(e), flush=True)
        out.append(rec)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = run_variants(args.cell, args.multi_pod)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1, default=str)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
