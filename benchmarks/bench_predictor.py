"""Paper §7.4.4 + Fig. 8: predictor runtime overhead and design-space
exploration (layers × hidden), plus Fig. 18 (training-data fraction).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches
from repro.config import SpecEEConfig
from repro.core import predictor as pred_lib
from repro.core import predictor_training as pt


def _time(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(timer: Timer) -> None:
    b = get_bundle()
    m, params, sw = b.model, b.params, b.sw
    spec = b.run.specee
    B = 8
    feats = jax.random.normal(jax.random.PRNGKey(0),
                              (B, spec.feature_dim()))

    # predictor runtime vs one decoder unit runtime (paper: 5.6% of token)
    pp = pred_lib.predictor_at(sw.predictors, jnp.int32(0))
    t_pred = _time(jax.jit(lambda f: pred_lib.apply_predictor(pp, f)), feats)
    cache = m.empty_cache(B, 32)
    h = jax.random.normal(jax.random.PRNGKey(1), (B, b.run.model.d_model))
    t_unit = _time(jax.jit(
        lambda hh: m.run_unit(params, 0, jnp.int32(0), hh,
                              cache["segments"][0], cache["len"])[0]), h)
    timer.add("predictor/runtime", t_pred * 1e6,
              f"unit={t_unit*1e6:.0f}us ratio={t_pred/t_unit:.3f}")

    # Fig. 8 DSE: layers × hidden
    batches = token_batches(b.run, 2)
    data = pt.collect_dataset(m, params, sw.draft, batches)
    for layers in (1, 2, 3):
        for hidden in (128, 512, 1024):
            s = SpecEEConfig(predictor_layers=layers, predictor_hidden=hidden)
            p, met = pt.train_predictors(s, data, jax.random.PRNGKey(3),
                                         steps=150)
            one = pred_lib.predictor_at(p, jnp.int32(0))
            t = _time(jax.jit(
                lambda f: pred_lib.apply_predictor(one, f)), feats, iters=20)
            timer.add(f"predictor/dse_L{layers}_H{hidden}", t * 1e6,
                      f"acc={met['accuracy']:.3f}")

    # Fig. 18: training-data fraction vs accuracy
    E, T, F = data.features.shape
    for frac in (0.02, 0.1, 0.5, 1.0):
        n = max(8, int(T * frac))
        sub = pt.FeatureDataset(features=data.features[:, :n],
                                labels=data.labels[:, :n])
        _, met = pt.train_predictors(b.run.specee, sub,
                                     jax.random.PRNGKey(4), steps=150)
        timer.add(f"predictor/data_frac_{frac}", 0.0,
                  f"acc={met['accuracy']:.3f} n={n}")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
