"""Paper §7.4.4 + Fig. 8: predictor runtime overhead and design-space
exploration (layers × hidden), plus Fig. 18 (training-data fraction), plus
the fused-vs-unfused exit-gate A/B (PR: fused exit-gate pipeline), which
records ``BENCH_exit_gate.json`` at the repo root so the perf trajectory of
the decode hot loop is tracked across PRs.

``BENCH_exit_gate.json`` schema — an object of named row-groups:
  * ``gate_ab``       — fused-vs-unfused gate timing + analytic ``hbm_bytes``
                        per (B, D, V, k) shape (this module);
  * ``quant_verify``  — fp vs int8/int4 streaming-verify timing; the
                        quantized rows carry ``wbits`` and their
                        ``hbm_bytes`` shrink with the weight width (this
                        module);
  * ``quant_pareto``  — quant level × exit threshold speed/quality sweep
                        (``bench_ablation.quant_pareto``).
A legacy top-level list is read back as the ``gate_ab`` group.

    python -m benchmarks.bench_predictor              # everything
    python -m benchmarks.bench_predictor --gate-only  # just the gate A/B
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches
from repro.config import SpecEEConfig
from repro.core import features as feat_lib
from repro.core import predictor as pred_lib
from repro.core import predictor_training as pt
from repro.kernels.exit_gate import ops as gate_ops


def _time(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(timer: Timer) -> None:
    b = get_bundle()
    m, params, sw = b.model, b.params, b.sw
    spec = b.run.specee
    B = 8
    feats = jax.random.normal(jax.random.PRNGKey(0),
                              (B, spec.feature_dim()))

    # predictor runtime vs one decoder unit runtime (paper: 5.6% of token)
    pp = pred_lib.predictor_at(sw.predictors, jnp.int32(0))
    t_pred = _time(jax.jit(lambda f: pred_lib.apply_predictor(pp, f)), feats)
    cache = m.empty_cache(B, 32)
    h = jax.random.normal(jax.random.PRNGKey(1), (B, b.run.model.d_model))
    t_unit = _time(jax.jit(
        lambda hh: m.run_unit(params, 0, jnp.int32(0), hh,
                              cache["segments"][0], cache["len"])[0]), h)
    timer.add("predictor/runtime", t_pred * 1e6,
              f"unit={t_unit*1e6:.0f}us ratio={t_pred/t_unit:.3f}")

    # Fig. 8 DSE: layers × hidden
    batches = token_batches(b.run, 2)
    data = pt.collect_dataset(m, params, sw.draft, batches)
    for layers in (1, 2, 3):
        for hidden in (128, 512, 1024):
            s = SpecEEConfig(predictor_layers=layers, predictor_hidden=hidden)
            p, met = pt.train_predictors(s, data, jax.random.PRNGKey(3),
                                         steps=150)
            one = pred_lib.predictor_at(p, jnp.int32(0))
            t = _time(jax.jit(
                lambda f: pred_lib.apply_predictor(one, f)), feats, iters=20)
            timer.add(f"predictor/dse_L{layers}_H{hidden}", t * 1e6,
                      f"acc={met['accuracy']:.3f}")

    # Fig. 18: training-data fraction vs accuracy
    E, T, F = data.features.shape
    for frac in (0.02, 0.1, 0.5, 1.0):
        n = max(8, int(T * frac))
        sub = pt.FeatureDataset(features=data.features[:, :n],
                                labels=data.labels[:, :n])
        _, met = pt.train_predictors(b.run.specee, sub,
                                     jax.random.PRNGKey(4), steps=150)
        timer.add(f"predictor/data_frac_{frac}", 0.0,
                  f"acc={met['accuracy']:.3f} n={n}")


# ---------------------------------------------------------------------------
# fused-vs-unfused exit-gate A/B
# ---------------------------------------------------------------------------
# (B, D, V, k): engine smoke scale, a 7B-ish decode shape, a 70B-ish one
GATE_SHAPES = [(8, 128, 512, 4), (4, 1024, 16000, 4), (8, 2048, 32000, 4)]

_GATE_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_exit_gate.json")


def _ab_time(fn_a, fn_b, args, iters: int = 5, rounds: int = 24):
    """Interleaved A/B timing, min over many short rounds — shared-machine
    noise bursts hit both paths symmetrically and the minimum converges to
    the quiet-machine cost instead of biasing whichever ran second."""
    fn_a(*args)
    fn_b(*args)  # compile both first
    best_a = best_b = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn_a(*args)
        jax.block_until_ready(out)
        best_a = min(best_a, (time.perf_counter() - t0) / iters)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn_b(*args)
        jax.block_until_ready(out)
        best_b = min(best_b, (time.perf_counter() - t0) / iters)
    return best_a, best_b


def _gate_bytes(B, D, V, k, wbytes=4):
    """Analytic per-exit-point HBM traffic (see kernels/exit_gate docstring).
    ``wbytes``: bytes per LM-head weight (4 fp32, 1 int8, 0.5 packed int4);
    quantized heads also stream their fp32 per-column scale row."""
    scales = V * 4 if wbytes < 4 else 0
    gather = k * D * wbytes
    head = D * V * wbytes + scales
    logits_round_trips = 3 * B * V * 4      # write + read + argmax read
    return {"unfused": gather + head + logits_round_trips,
            "fused": gather + head}


def bench_exit_gate(timer: Timer) -> list:
    """Per-exit-point wall time: the engine's historical four separately
    dispatched XLA ops vs. ONE call through the fused ``exit_gate`` +
    ``verify_argmax`` entry points (auto impl: Pallas on TPU, fused-XLA on
    CPU). The Pallas chain itself is additionally timed in interpret mode at
    the smoke shape as a correctness-path datapoint, not a perf claim."""
    rows = []
    for B, D, V, k in GATE_SHAPES:
        spec = SpecEEConfig(num_speculative=k)
        bank = pred_lib.init_predictors(spec, 12, jax.random.PRNGKey(0))
        hn = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        lm_w = jax.random.normal(jax.random.PRNGKey(2), (D, V)) * 0.05
        ids = jax.random.randint(jax.random.PRNGKey(3), (B, k), 0, V)
        prev = jnp.full((B, k), 1.0 / k)
        ep = jnp.int32(3)

        # unfused: the pre-PR decode-loop sequence, one dispatch per stage
        f_feat = jax.jit(lambda hn, w, i, p: feat_lib.extract_features(
            hn, w, i, p))
        f_pred = jax.jit(lambda bk, e, ft: pred_lib.apply_predictor(
            pred_lib.predictor_at(bk, e), ft))
        f_logits = jax.jit(lambda hn, w: (hn @ w.astype(hn.dtype))
                           .astype(jnp.float32))
        f_verify = jax.jit(lambda gl, i: (
            jnp.argmax(gl, -1).astype(jnp.int32),
            jnp.any(jnp.argmax(gl, -1)[:, None] == i, 1)))

        def unfused(hn, lm_w, ids, prev, bank, ep):
            feats, probs = f_feat(hn, lm_w, ids, prev)
            p_exit = f_pred(bank, ep, feats)
            glogits = f_logits(hn, lm_w)
            tok, hit = f_verify(glogits, ids)
            return p_exit, probs, tok, hit

        @jax.jit
        def fused(hn, lm_w, ids, prev, bank, ep):
            p_exit, probs, _ = gate_ops.exit_gate(hn, lm_w, ids, prev,
                                                  bank, ep)
            tok, _ = gate_ops.verify_argmax(hn, lm_w)
            return p_exit, probs, tok, jnp.any(tok[:, None] == ids, 1)

        t_unfused, t_fused = _ab_time(unfused, fused,
                                      (hn, lm_w, ids, prev, bank, ep))
        row = {"B": B, "D": D, "V": V, "k": k,
               "unfused_us": t_unfused * 1e6, "fused_us": t_fused * 1e6,
               "speedup": t_unfused / t_fused,
               "hbm_bytes": _gate_bytes(B, D, V, k),
               "backend": jax.default_backend()}
        # the Pallas chain itself, for EVERY sweep row (off-TPU it runs in
        # interpret mode — a correctness-path datapoint, so the big shapes
        # use few iterations; on TPU this is the headline column)
        @jax.jit
        def fused_kernel(hn, lm_w, ids, prev, bank, ep):
            p_exit, probs, _ = gate_ops.exit_gate(
                hn, lm_w, ids, prev, bank, ep, impl="kernel")
            tok, _ = gate_ops.verify_argmax(hn, lm_w, impl="kernel")
            return p_exit, probs, tok
        kiters = 10 if (B, D, V, k) == GATE_SHAPES[0] or \
            jax.default_backend() == "tpu" else 1
        row["fused_kernel_us"] = _time(
            fused_kernel, hn, lm_w, ids, prev, bank, ep, iters=kiters) * 1e6
        rows.append(row)
        timer.add(f"exit_gate/B{B}_D{D}_V{V}", row["fused_us"],
                  f"unfused={row['unfused_us']:.1f}us "
                  f"speedup={row['speedup']:.2f}x")
    from benchmarks.common import merge_bench_json
    merge_bench_json(_GATE_JSON, "gate_ab", rows)
    return rows


def bench_quant_verify(timer: Timer) -> list:
    """fp vs int8/int4 streaming verify at each gate shape.

    Times the streaming impl the platform actually uses (Pallas kernel on
    TPU, XLA scan off-TPU) with the fp LM head against the quantized one;
    the quantized rows' analytic ``hbm_bytes`` shrink with the weight width
    (int8 ≈ 4×, packed int4 ≈ 8× less head traffic plus the fp32 scale
    row) — the memory-bound decode win the fused dequant buys. Written to
    the ``quant_verify`` row-group of ``BENCH_exit_gate.json``."""
    from benchmarks.common import merge_bench_json
    from repro.kernels import on_tpu
    from repro.quant import quantize_tensor

    impl = "kernel" if on_tpu() else "xla"
    rows = []
    for B, D, V, k in GATE_SHAPES:
        hn = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        lm_w = jax.random.normal(jax.random.PRNGKey(2), (D, V)) * 0.05
        f_fp = jax.jit(lambda h, w: gate_ops.verify_argmax(h, w, impl=impl))
        for bits, wbytes in ((8, 1), (4, 0.5)):
            qt = quantize_tensor(lm_w, bits)
            f_q = jax.jit(lambda h, q: gate_ops.verify_argmax(h, q,
                                                              impl=impl))
            t_fp, t_q = _ab_time(lambda h: f_fp(h, lm_w),
                                 lambda h: f_q(h, qt), (hn,),
                                 iters=5, rounds=8)
            bytes_fp = _gate_bytes(B, D, V, k)
            bytes_q = _gate_bytes(B, D, V, k, wbytes=wbytes)
            rows.append({"B": B, "D": D, "V": V, "k": k, "wbits": bits,
                         "impl": impl,
                         "verify_fp_us": t_fp * 1e6,
                         "verify_q_us": t_q * 1e6,
                         "hbm_bytes_fp": bytes_fp,
                         "hbm_bytes": bytes_q,
                         "hbm_reduction":
                             bytes_fp["fused"] / bytes_q["fused"],
                         "backend": jax.default_backend()})
            timer.add(f"quant_verify/D{D}_V{V}_q{bits}", t_q * 1e6,
                      f"fp={t_fp*1e6:.1f}us "
                      f"hbm={bytes_fp['fused']/bytes_q['fused']:.2f}x less")
    merge_bench_json(_GATE_JSON, "quant_verify", rows)
    return rows


# ---------------------------------------------------------------------------
# CI perf gate over the committed BENCH_exit_gate.json (ROADMAP item 5)
# ---------------------------------------------------------------------------
def _load_groups() -> dict:
    if not os.path.exists(_GATE_JSON):
        return {}
    with open(_GATE_JSON) as f:
        data = json.load(f)
    if isinstance(data, list):          # legacy layout: bare gate_ab rows
        data = {"gate_ab": data}
    return data


def _fused_gate_time(B, D, V, k, iters=5, rounds=6) -> float:
    """Re-measure ONLY the fused path of ``bench_exit_gate`` (the gate's hot
    column) at a committed shape — min over short rounds, same estimator as
    ``_ab_time`` so fresh and committed numbers are comparable."""
    spec = SpecEEConfig(num_speculative=k)
    bank = pred_lib.init_predictors(spec, 12, jax.random.PRNGKey(0))
    hn = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    lm_w = jax.random.normal(jax.random.PRNGKey(2), (D, V)) * 0.05
    ids = jax.random.randint(jax.random.PRNGKey(3), (B, k), 0, V)
    prev = jnp.full((B, k), 1.0 / k)
    ep = jnp.int32(3)

    @jax.jit
    def fused(hn, lm_w, ids, prev, bank, ep):
        p_exit, probs, _ = gate_ops.exit_gate(hn, lm_w, ids, prev, bank, ep)
        tok, _ = gate_ops.verify_argmax(hn, lm_w)
        return p_exit, probs, tok, jnp.any(tok[:, None] == ids, 1)

    args = (hn, lm_w, ids, prev, bank, ep)
    fused(*args)                        # compile
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fused(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _quant_verify_time(B, D, V, bits, iters=3, rounds=6):
    """Re-measure the quantized streaming verify (``verify_q_us``) at a
    committed shape. Returns (impl, seconds)."""
    from repro.kernels import on_tpu
    from repro.quant import quantize_tensor

    impl = "kernel" if on_tpu() else "xla"
    hn = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    lm_w = jax.random.normal(jax.random.PRNGKey(2), (D, V)) * 0.05
    qt = quantize_tensor(lm_w, bits)
    f_q = jax.jit(lambda h, q: gate_ops.verify_argmax(h, q, impl=impl))
    f_q(hn, qt)                         # compile
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = f_q(hn, qt)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return impl, best


_GATE_ABS_SLACK_US = 250.0      # absolute noise floor added to every ceiling


def gate(threshold: float = 0.5) -> int:
    """CI perf gate over the committed ``BENCH_exit_gate.json`` row groups
    (ROADMAP item 5, the exit-gate counterpart of ``bench_serving --gate``):

      * ``gate_ab``       — re-measure the fused gate per committed shape and
                            fail when fresh > (1 + threshold) × committed
                            ``fused_us``; re-derive the analytic
                            ``hbm_bytes``, which must match EXACTLY (formula
                            drift silently rewrites the memory story).
                            ``fused_kernel_us`` is never re-measured here:
                            off-TPU it runs the Pallas chain in interpret
                            mode (minutes per call at the big shapes) and is
                            a correctness datapoint, not a perf claim.
      * ``quant_verify``  — re-measure the quantized streaming verify
                            (``verify_q_us``) under the same criterion;
                            rows recorded with another impl are skipped.
      * ``quant_pareto``  — produced by the heavyweight bench_ablation
                            sweep, so the gate checks the committed quality
                            column instead: ``match_vs_dense_fp32`` must be
                            1.0 (quantized SpecEE serving is lossless vs
                            dense fp32 by construction).

    Microsecond timings on a shared CPU are far noisier than serving
    throughput, hence the wide default threshold PLUS an absolute slack
    (``_GATE_ABS_SLACK_US``) on every ceiling: the smallest committed rows
    are tens of microseconds of pure dispatch overhead, where scheduler
    jitter alone exceeds any relative bound — the slack drowns that noise
    while leaving the millisecond-scale rows (the real memory-bound signal)
    gated at ~threshold. Rows recorded on another backend are skipped.
    Returns a process exit code."""
    groups = _load_groups()
    if not groups:
        print("[bench_predictor] --gate: no committed BENCH_exit_gate.json; "
              "skipping")
        return 0
    backend = jax.default_backend()
    failures, checked = [], 0
    for row in groups.get("gate_ab", []):
        if row.get("backend") != backend or not row.get("fused_us"):
            continue
        B, D, V, k = row["B"], row["D"], row["V"], row["k"]
        checked += 1
        bytes_now = _gate_bytes(B, D, V, k)
        if bytes_now != row.get("hbm_bytes"):
            print(f"[gate] gate_ab B{B}_D{D}_V{V}: hbm_bytes drift "
                  f"{bytes_now} != {row.get('hbm_bytes')} FAIL")
            failures.append(f"gate_ab/B{B}_D{D}_V{V}/hbm_bytes")
        fresh = _fused_gate_time(B, D, V, k) * 1e6
        ceil = (1.0 + threshold) * row["fused_us"] + _GATE_ABS_SLACK_US
        verdict = "OK" if fresh <= ceil else "FAIL"
        print(f"[gate] gate_ab    B{B}_D{D}_V{V:<6} fused={fresh:10.1f}us "
              f"vs committed {row['fused_us']:10.1f} (ceil {ceil:10.1f}) "
              f"{verdict}")
        if verdict == "FAIL":
            failures.append(f"gate_ab/B{B}_D{D}_V{V}")
    for row in groups.get("quant_verify", []):
        if row.get("backend") != backend or not row.get("verify_q_us"):
            continue
        B, D, V, bits = row["B"], row["D"], row["V"], row["wbits"]
        impl, fresh_s = _quant_verify_time(B, D, V, bits)
        if row.get("impl") != impl:
            continue                    # recorded with another verify impl
        checked += 1
        fresh = fresh_s * 1e6
        ceil = (1.0 + threshold) * row["verify_q_us"] + _GATE_ABS_SLACK_US
        verdict = "OK" if fresh <= ceil else "FAIL"
        print(f"[gate] quant_q{bits}  B{B}_D{D}_V{V:<6} "
              f"verify={fresh:10.1f}us vs committed "
              f"{row['verify_q_us']:10.1f} (ceil {ceil:10.1f}) {verdict}")
        if verdict == "FAIL":
            failures.append(f"quant_verify/B{B}_D{D}_V{V}_q{bits}")
    for row in groups.get("quant_pareto", []):
        if row.get("backend") != backend:
            continue
        checked += 1
        match = row.get("match_vs_dense_fp32")
        verdict = "OK" if match == 1.0 else "FAIL"
        print(f"[gate] pareto     {row.get('quant', '?'):5s} "
              f"thr={row.get('threshold')}: match_vs_dense_fp32={match} "
              f"{verdict}")
        if verdict == "FAIL":
            failures.append(
                f"quant_pareto/{row.get('quant')}@{row.get('threshold')}")
    if failures:
        print(f"[gate] FAIL: exit-gate regression (> {threshold:.0%} or "
              f"drift) in {failures}")
        return 1
    print(f"[gate] OK: {checked} rows within {threshold:.0%} of committed")
    return 0


if __name__ == "__main__":
    if "--gate" in sys.argv:
        thr = 0.5
        if "--gate-threshold" in sys.argv:
            thr = float(sys.argv[sys.argv.index("--gate-threshold") + 1])
        sys.exit(gate(threshold=thr))
    t = Timer()
    if "--gate-only" in sys.argv:
        bench_exit_gate(t)
        bench_quant_verify(t)
    else:
        run(t)
        bench_exit_gate(t)
        bench_quant_verify(t)
    t.emit()
