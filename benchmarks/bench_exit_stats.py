"""Paper Fig. 10/11: exit-layer distribution (skew) and context similarity
(hit ratio of the current exit within ±2 of the last N exits)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches, decode_run


def run(timer: Timer) -> None:
    b = get_bundle()
    E = b.model.num_exit_points
    prompts = token_batches(b.run, 1, B=4, S=16, seed=41)[0]
    spec = decode_run(b, "specee_t1", prompts, new_tokens=24, threshold=0.35)
    exits = np.minimum(spec["exit_points"], E)      # (steps, B)
    hist = np.bincount(exits.flatten(), minlength=E + 1)
    timer.add("exit_stats/histogram", 0.0,
              "counts=" + "/".join(str(int(x)) for x in hist))
    # skew: bottom-50% layers' share of exits (paper: <20%)
    h = hist[:E].astype(float)
    if h.sum() > 0:
        order = np.sort(h)
        bottom = order[: E // 2].sum() / max(h.sum(), 1)
        timer.add("exit_stats/bottom50_share", 0.0, f"{bottom:.2f}")
    # context similarity: exit within ±2 of one of the previous N exits
    for N in (1, 3, 5):
        hits, total = 0, 0
        for bb in range(exits.shape[1]):
            seq = exits[:, bb]
            for t in range(N, len(seq)):
                if seq[t] >= E:   # no exit
                    continue
                total += 1
                if any(abs(int(seq[t]) - int(s)) <= 2
                       for s in seq[t - N:t] if s < E):
                    hits += 1
        ratio = hits / total if total else 0.0
        timer.add(f"exit_stats/ctx_similarity_N{N}", 0.0,
                  f"hit={ratio:.2f} n={total}")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
