"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

    compute term    = FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
    memory term     = HBM_bytes_per_device / HBM_bw           (819 GB/s)
    collective term = collective_bytes_per_device / link_bw   (~50 GB/s ICI)

Sources — a HYBRID of the compiled dry-run artifact and analytic counts,
because XLA's ``cost_analysis`` counts ``scan``/``while`` bodies exactly once
(we verified: unrolled lowering of deepseek-7b train reports 30× the scanned
FLOPs). Per term:

* compute — analytic MODEL/HLO hybrid: dense-matmul FLOPs 6·N·D (train) or
  2·N_active·D (inference) + exact attention terms; HLO flops (body-once) are
  reported as a cross-check column. The chunked-attention implementation does
  not causally prune (static shapes), so compiled attention FLOPs are ~2× the
  causal ideal — the ratio column accounts for it.
* memory — per-device: all sharded argument bytes (weights + optimizer + KV,
  measured from the dry-run shardings) + analytic activation traffic
  (r/w per layer per token); decode ≈ one full pass over weights+cache per
  token, which IS the argument size.
* collective — parsed from ``compiled.as_text()``: ENTRY-computation
  collectives count once; loop-body collectives scale by the layer-loop trip
  count recorded by the dry-run (inner chunk-loop collectives are counted at
  layer multiplicity — stated approximation).

Usage:
    python -m benchmarks.roofline --dryrun artifacts/dryrun_single_pod.json \
        --md artifacts/roofline.md --json artifacts/roofline.json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from repro.config import ATTN, LOCAL_ATTN, SSD, RGLRU, shape_by_name
from repro.configs import get_config

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def analytic_flops(arch: str, shape_name: str) -> Dict[str, float]:
    """Useful-model FLOPs per step (GLOBAL) + implementation FLOPs.

    model:  causal-ideal attention;  impl: our chunked attention computes the
    full S×S score matrix (no causal block pruning) -> ~2× attention term.
    """
    run = get_config(arch)
    cfg = run.model
    cell = shape_by_name(shape_name)
    N = cfg.active_param_count()
    B, S = cell.global_batch, cell.seq_len
    d = (cfg.num_heads * cfg.resolved_head_dim()) if cfg.num_heads else 0
    blocks = cfg.blocks()
    L_attn = sum(1 for k in blocks if k in (ATTN, LOCAL_ATTN))
    win = run.model.rglru.window if cfg.rglru else None

    def attn(tokens_q, kv_len, causal_frac):
        if d == 0 or L_attn == 0:
            return 0.0
        eff = min(kv_len, win) if win else kv_len
        return L_attn * 4 * d * tokens_q * eff * causal_frac

    if cell.kind == "train":
        tokens = B * S
        model = 6 * N * tokens + 3 * attn(tokens, S, 0.5)
        impl = 6 * N * tokens * (4 / 3) + 3 * attn(tokens, S, 1.0)  # +remat fwd
    elif cell.kind == "prefill":
        tokens = B * S
        model = 2 * N * tokens + attn(tokens, S, 0.5)
        impl = 2 * N * tokens + attn(tokens, S, 1.0)
    else:  # decode: one token/row, full-depth upper bound (no early exit)
        tokens = B
        model = 2 * N * B + attn(B, S, 1.0)
        impl = model
    return {"model_flops": model, "impl_flops": impl, "tokens": tokens}


def analytic_hbm_bytes(arch: str, shape_name: str, rec: Dict) -> float:
    """Per-device HBM traffic per step: arguments (weights/opt/KV, measured
    from the dry-run shardings) + activation r/w traffic."""
    run = get_config(arch)
    cfg = run.model
    cell = shape_by_name(shape_name)
    devices = rec.get("devices", 256)
    args = rec.get("analytic_arg_bytes_per_device", 0)
    B, S = cell.global_batch, cell.seq_len
    L, D = cfg.num_layers, cfg.d_model
    act_bytes = 2  # bf16
    if cell.kind == "train":
        # fwd+bwd+recompute: ~20 r/w of (B,S,D) per layer, batch-sharded;
        # plus one more full pass over params (grads) and opt update (3x fp32)
        acts = 20 * L * B * S * D * act_bytes / devices
        grads_opt = rec.get("analytic_arg_bytes_per_device", 0) * 2
        return args + acts + grads_opt
    if cell.kind == "prefill":
        acts = 12 * L * B * S * D * act_bytes / devices
        return args + acts
    # decode: weights + valid KV once per token + O(L·B·D) activations
    return args + 10 * L * B * D * act_bytes / devices


def roofline_terms(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if "error" in rec:
        return None
    devices = rec.get("devices", 256)
    af = analytic_flops(rec["arch"], rec["shape"])
    flops_dev = af["impl_flops"] / devices
    model_dev = af["model_flops"] / devices
    hbm = analytic_hbm_bytes(rec["arch"], rec["shape"], rec)
    exact = rec.get("collectives_exact")
    scale = rec.get("loop_scale", 1)
    if exact:  # trip-count-aware call-graph accounting (preferred)
        coll_bytes = exact["total_bytes"]
    else:      # fallback: entry + loop-body × layer-loop scale
        coll = rec.get("collectives", {})
        coll_bytes = (coll.get("entry_bytes", 0.0) +
                      coll.get("loop_bytes", 0.0) * scale)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = coll_bytes / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_comp, t_mem, t_coll)
    # ideal achievable step time: the model's FLOPs at peak, OR the
    # irreducible byte traffic (weights+opt+valid KV must be read once per
    # step) at full HBM bandwidth — whichever is larger. Decode is memory-
    # ideal (reads dominate); train/prefill are compute-ideal.
    required_bytes = rec.get("analytic_arg_bytes_per_device", 0)
    useful_time = max(model_dev / PEAK_FLOPS, required_bytes / HBM_BW)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec.get("mesh"),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": af["model_flops"],
        "useful_flops_ratio": model_dev / flops_dev if flops_dev else 0.0,
        "roofline_fraction": (useful_time / bound) if bound else 0.0,
        "hlo_flops_bodyonce": rec.get("cost", {}).get("flops"),
        "collective_bytes": coll_bytes,
        "loop_scale": scale,
        "compile_s": rec.get("compile_s"),
        "arg_gb_per_device": rec.get("analytic_arg_bytes_per_device", 0) / 2**30,
        "temp_gb_per_device": rec.get("memory", {}).get(
            "temp_size_in_bytes", 0) / 2**30,
    }


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def to_markdown(rows: List[Optional[Dict[str, Any]]]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | dominant "
           "| useful/impl FLOPs | roofline frac | args GB/dev | temp GB/dev |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r is None:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.1%} | "
            f"{r['arg_gb_per_device']:.2f} | {r['temp_gb_per_device']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", nargs="+", required=True)
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    recs: List[Dict[str, Any]] = []
    for fn in args.dryrun:
        with open(fn) as f:
            recs.extend(json.load(f))
    rows = [roofline_terms(r) for r in recs]
    md = to_markdown(rows)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r for r in rows if r], f, indent=1)


if __name__ == "__main__":
    main()
