"""Paper Fig. 17 + §7.4.2: memory accounting — target model vs +DLM vs
+predictors (measured byte counts, full-scale analytic for Llama2-7B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, get_bundle
from repro.configs import get_config
from repro.core import draft as draft_lib
from repro.core import predictor as pred_lib


def _bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def run(timer: Timer) -> None:
    b = get_bundle()
    target = _bytes(b.params)
    draft = _bytes(b.sw.draft)
    preds = _bytes(b.sw.predictors)
    timer.add("memory/smoke_target", 0.0, f"{target/2**20:.2f}MiB")
    timer.add("memory/smoke_draft", 0.0,
              f"{draft/2**20:.2f}MiB ({draft/target:.1%} of target)")
    timer.add("memory/smoke_predictors", 0.0,
              f"{preds/2**10:.1f}KiB ({preds/target:.2%} of target)")

    # full-scale analytic (Llama2-7B, paper's numbers: DLM ≈ 0.9 GB bf16,
    # predictors ≈ 416 KB fp16)
    full = get_config("llama2-7b")
    n_t = full.model.param_count()
    n_d = draft_lib.draft_param_count(full.model)
    p_b = pred_lib.predictor_param_bytes(full.specee, full.model.num_layers)
    timer.add("memory/llama7b_target", 0.0, f"{n_t*2/2**30:.2f}GiB bf16")
    timer.add("memory/llama7b_draft", 0.0,
              f"{n_d*2/2**30:.2f}GiB bf16 ({n_d/n_t:.1%} of params — paper: "
              f"~0.9GB extra)")
    timer.add("memory/llama7b_predictors", 0.0,
              f"{p_b/2**10:.0f}KiB fp32 (paper: 416KiB fp16)")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
