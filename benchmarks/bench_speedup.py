"""Paper Fig. 14 analogue: decoding speedup & throughput, dense vs SpecEE.

CPU smoke-scale measurement of the real engines (batch 1 = the paper's
latency scenario; batch 2 = slot-parallel), plus the paper's own speedup
model: E / (avg_exit + draft_overhead_layers) using measured exits.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches, decode_run


def run(timer: Timer) -> None:
    b = get_bundle()
    E = b.model.num_exit_points
    prompts = token_batches(b.run, 1, B=1, S=16, seed=11)[0]
    dense = decode_run(b, "dense", prompts, new_tokens=24)
    spec = decode_run(b, "specee", prompts, new_tokens=24)
    speedup = dense["seconds"] / spec["seconds"]
    # the paper's theoretical model (§5.1): layers / (avg exit + 1 draft-layer)
    theo = E / (spec["avg_exit"] + 1.0)
    timer.add("speedup/dense_tok_s", 1e6 / dense["tok_per_s"],
              f"tok/s={dense['tok_per_s']:.2f}")
    timer.add("speedup/specee_tok_s", 1e6 / spec["tok_per_s"],
              f"tok/s={spec['tok_per_s']:.2f}")
    timer.add("speedup/end_to_end", spec["seconds"] / 24 * 1e6,
              f"speedup={speedup:.2f}x avg_exit={spec['avg_exit']:.2f}/{E} "
              f"theoretical={theo:.2f}x "
              f"draft_topk_hit={b.draft_metrics['topk_hit_rate']:.2f}")
    # greedy-agreement between the two engines (accuracy guard, Table 4)
    agree = float(np.mean(dense["tokens"] == spec["tokens"]))
    timer.add("speedup/greedy_agreement", 0.0, f"agree={agree:.3f}")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
