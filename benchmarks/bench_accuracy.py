"""Paper Table 4 analogue: accuracy (greedy agreement vs the dense model —
the verification guarantee) and average forward layers per dataset-like
synthetic stream."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches, decode_run


def run(timer: Timer) -> None:
    b = get_bundle()
    E = b.model.num_exit_points
    for name, seed in (("synthA", 21), ("synthB", 22), ("synthC", 23)):
        prompts = token_batches(b.run, 1, B=2, S=12, seed=seed)[0]
        dense = decode_run(b, "dense", prompts, new_tokens=16)
        spec = decode_run(b, "specee", prompts, new_tokens=16)
        agree = float(np.mean(dense["tokens"] == spec["tokens"]))
        timer.add(f"accuracy/{name}", 0.0,
                  f"agree={agree:.3f} avg_layers={spec['avg_exit']:.2f}/{E} "
                  f"dense_layers={E}")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
