"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (deliverable d). All benchmarks run
the REAL engines on a smoke-scale Llama2-7B with a trained draft + trained
predictors (see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run [--only speedup,ablation]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import Timer, get_bundle

MODULES = [
    ("speedup", "benchmarks.bench_speedup"),        # paper Fig. 14
    ("accuracy", "benchmarks.bench_accuracy"),      # paper Table 4
    ("ablation", "benchmarks.bench_ablation"),      # paper Fig. 19
    ("predictor", "benchmarks.bench_predictor"),    # paper §7.4.4 / Fig. 8/18
    ("exit_stats", "benchmarks.bench_exit_stats"),  # paper Fig. 10/11
    ("memory", "benchmarks.bench_memory"),          # paper Fig. 17 / §7.4.2
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("# building trained SpecEE bundle (target+draft+predictors)...",
          file=sys.stderr)
    t0 = time.time()
    b = get_bundle()
    print(f"# bundle ready in {time.time()-t0:.0f}s: "
          f"draft_topk_hit={b.draft_metrics['topk_hit_rate']:.2f} "
          f"predictor_acc={b.predictor_metrics['accuracy']:.2f}",
          file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if only and name not in only:
            continue
        timer = Timer()
        try:
            __import__(mod, fromlist=["run"]).run(timer)
        except Exception:
            traceback.print_exc()
            failures += 1
            timer.add(f"{name}/ERROR", 0.0, "exception")
        timer.emit()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
