"""Serving-path A/B: paged vs dense KV cache, chunked vs blocking prefill.

Records ``BENCH_serving.json`` at the repo root so the serving hot loop's
perf trajectory is tracked across PRs, mirroring ``BENCH_exit_gate.json``:

* tokens/s for a fixed request set through ``ServingEngine``, at 2–3 batch
  sizes, paged vs dense cache and chunked vs blocking admission;
* decode tick latency (min over interleaved rounds — the same
  noise-symmetric min-timing harness as ``bench_predictor``).

CPU numbers are correctness-path datapoints, not perf claims: the paged win
(skipped pages = skipped HBM traffic) and the chunked win (no head-of-line
prompt stalls) are TPU stories; what this harness pins is that the managed
cache and the scheduler do not regress the tick loop.

    python -m benchmarks.bench_serving
    python -m benchmarks.bench_serving --batches 2 4 --rounds 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.serving import ServingEngine

_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "BENCH_serving.json")


def _requests(run, n, seed=0, lo=6, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(lo, hi)))
            for _ in range(n)]


def _one_round(se, prompts, max_new):
    """Submit + drain one request set; returns (tokens, wall_s, ticks,
    min_tick_s). The engine is reused across rounds so jit caches stay warm
    (compile cost lands in the warmup round only)."""
    for p in prompts:
        se.submit(p, max_new_tokens=max_new)
    ticks = 0
    min_tick = float("inf")
    toks = 0
    t0 = time.perf_counter()
    while True:
        t1 = time.perf_counter()
        done = se.step()
        dt = time.perf_counter() - t1
        ticks += 1
        min_tick = min(min_tick, dt)
        toks += sum(len(r.output) for r in done)
        if (not se.scheduler.has_work()
                and not np.any(se.session.live_rows())):
            break
    return toks, time.perf_counter() - t0, ticks, min_tick


def bench(batches, rounds, max_new, requests_per_slot):
    base = get_config("llama2-7b").smoke()
    rows = []
    for B in batches:
        run = dataclasses.replace(
            base, serve=dataclasses.replace(base.serve, max_batch=B))
        model = build_model(run)
        params = model.init(jax.random.PRNGKey(0))
        sw = eng.init_specee(model, jax.random.PRNGKey(1))
        prompts = _requests(run, B * requests_per_slot, seed=B)

        variants = {
            "paged+chunked": dict(cache="paged"),
            "paged+blocking": dict(cache="paged", prefill_chunk=0),
            "dense+chunked": dict(cache="dense"),
            "dense+blocking": dict(cache="dense", prefill_chunk=0),
        }
        engines = {name: ServingEngine(model, params, sw, strategy="specee",
                                       **kw)
                   for name, kw in variants.items()}
        best = {name: {"tok_s": 0.0, "tick_us": float("inf")}
                for name in variants}
        for name, se in engines.items():            # warmup (compile)
            _one_round(se, prompts, max_new)
        for _ in range(rounds):                     # interleaved min-timing
            for name, se in engines.items():
                toks, dt, ticks, min_tick = _one_round(se, prompts, max_new)
                best[name]["tok_s"] = max(best[name]["tok_s"], toks / dt)
                best[name]["tick_us"] = min(best[name]["tick_us"],
                                            min_tick * 1e6)
                best[name]["ticks"] = ticks
                best[name]["tokens"] = toks
        for name in variants:
            se = engines[name]
            row = {"batch": B, "variant": name,
                   "cache": se.cache_spec.kind,
                   "prefill_chunk": se.scheduler.chunk_tokens or 0,
                   "page_size": se.cache_spec.page_size,
                   "tokens_per_s": round(best[name]["tok_s"], 2),
                   "min_tick_us": round(best[name]["tick_us"], 1),
                   "ticks": best[name]["ticks"],
                   "tokens": best[name]["tokens"],
                   "backend": jax.default_backend()}
            rows.append(row)
            print(f"[bench_serving] B={B} {name:16s} "
                  f"{row['tokens_per_s']:8.1f} tok/s  "
                  f"tick={row['min_tick_us']:8.1f}us  ticks={row['ticks']}")
    with open(_JSON, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[bench_serving] wrote {_JSON}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--requests-per-slot", type=int, default=2)
    args = ap.parse_args()
    bench(args.batches, args.rounds, args.max_new, args.requests_per_slot)
