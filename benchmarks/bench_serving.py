"""Serving-path A/B: paged vs dense KV cache, chunked vs blocking prefill,
and megatick K ∈ {1, 4, 16} device-resident decode.

Records ``BENCH_serving.json`` at the repo root so the serving hot loop's
perf trajectory is tracked across PRs, mirroring ``BENCH_exit_gate.json``.

Admission cost and decode throughput are reported SEPARATELY (conflating
them made blocking variants read as slow *decoders* when they were slow
*admitters* — eager whole-prompt prefill dominated the old single number):

* ``admission_ms`` / ``admission_ticks`` — wall time from first submit until
  the scheduler has admitted every request (blocking pays it all here;
  chunked spreads it across ticks);
* ``decode_tok_s`` — steady-state decode throughput measured ONLY after
  admission has drained, every slot live from tick one;
* ``tokens_per_s`` — the old whole-round number, kept for continuity;
* ``min_tick_us`` — min ``step()`` wall time during the decode phase (for a
  megatick-K engine one step covers up to K device ticks).

The megatick rows A/B the device-resident K-step ``lax.while_loop`` + async
pipeline against the per-tick host-synced loop: on CPU at smoke scale the
regime is exactly the host-sync-dominated one the megatick targets, so
decode_tok_s should scale strongly with K (acceptance: ≥2× at K=16 vs K=1).

Sharded rows (DESIGN.md §9): ``--tp N`` runs the same variants under a
(1, N) tensor-parallel mesh on forced host devices and labels the rows
``mesh="tpN"``; ``--tp-sweep`` re-execs itself for N ∈ {1, 2, 4} (a fresh
process per degree — the forced-host-device flag must precede jax backend
init). Rows MERGE into BENCH_serving.json by (batch, variant, mesh), so a
sweep extends the committed table instead of clobbering the other rows.

``--gate`` is the CI perf gate (ROADMAP item 5): re-measures a small config
and compares ``decode_tok_s`` against the committed rows, failing (exit 1)
on a >20% regression. Rows with no committed counterpart (or a different
backend) are skipped, so the gate degrades gracefully on fresh checkouts.

    python -m benchmarks.bench_serving
    python -m benchmarks.bench_serving --batches 2 4 --rounds 4
    python -m benchmarks.bench_serving --tp-sweep
    python -m benchmarks.bench_serving --gate
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.serving import ServingEngine

_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "BENCH_serving.json")

# the variant subset sharded / gate runs measure (the serving hot paths;
# the full grid at tp1 stays the default)
_CORE_VARIANTS = ("paged+chunked", "paged+chunked+mt4", "paged+chunked+mt16")


def _load_rows():
    if not os.path.exists(_JSON):
        return []
    with open(_JSON) as f:
        rows = json.load(f)
    for r in rows:                      # rows predating the mesh column
        r.setdefault("mesh", "tp1")
    return rows


def _merge_rows(new):
    """Read-modify-write by (batch, variant, mesh) — a TP sweep or a partial
    re-run updates its own rows and leaves the rest of the table alone."""
    rows = _load_rows()
    key = lambda r: (r["batch"], r["variant"], r["mesh"])  # noqa: E731
    have = {key(r): i for i, r in enumerate(rows)}
    for r in new:
        k = key(r)
        if k in have:
            rows[have[k]] = r
        else:
            have[k] = len(rows)
            rows.append(r)
    rows.sort(key=lambda r: (r["mesh"], r["batch"], r["variant"]))
    with open(_JSON, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def _requests(run, n, seed=0, lo=6, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(lo, hi)))
            for _ in range(n)]


def _one_round(se, prompts, max_new):
    """Submit one request per slot, then measure the two phases apart:
    admission (until the scheduler drains) and steady-state decode (all
    slots live). Returns a dict of phase numbers. The engine is reused
    across rounds so jit caches stay warm (compile cost lands in the warmup
    round only)."""
    reqs = [se.submit(p, max_new_tokens=max_new) for p in prompts]
    t0 = time.perf_counter()
    adm_ticks = 0
    while se.scheduler.has_work():
        se.step()
        adm_ticks += 1
    t_admit = time.perf_counter() - t0
    # async variants: a megatick dispatched inside the admission window may
    # still be in flight — retire it BEFORE snapshotting the decode baseline,
    # or its tokens (compute that overlapped the admission timer) leak into
    # the decode phase and inflate decode_tok_s in proportion to K
    if se.in_flight:
        se.step()
    toks0 = sum(len(r.output) for r in reqs)
    ticks = 0
    min_tick = float("inf")
    t1 = time.perf_counter()
    while se.busy:
        t2 = time.perf_counter()
        se.step()
        min_tick = min(min_tick, time.perf_counter() - t2)
        ticks += 1
    t_decode = time.perf_counter() - t1
    toks = sum(len(r.output) for r in reqs)
    assert all(r.done for r in reqs)
    return {"tokens": toks, "wall_s": t_admit + t_decode,
            "admission_s": t_admit, "admission_ticks": adm_ticks,
            "decode_tokens": toks - toks0, "decode_s": t_decode,
            "decode_ticks": ticks, "min_tick_s": min_tick}


def bench(batches, rounds, max_new, tp=1, variants_filter=None, write=True):
    base = get_config("llama2-7b").smoke()
    mesh_label = f"tp{tp}"
    mesh = None
    if tp > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, tp)
        if variants_filter is None:
            variants_filter = _CORE_VARIANTS
    rows = []
    for B in batches:
        run = dataclasses.replace(
            base, serve=dataclasses.replace(base.serve, max_batch=B))
        model = build_model(run)
        params = model.init(jax.random.PRNGKey(0))
        sw = eng.init_specee(model, jax.random.PRNGKey(1))
        prompts = _requests(run, B, seed=B)

        variants = {
            # cache layout × admission policy (megatick 1, blocking ticks —
            # the historical serving loop)
            "paged+chunked": dict(cache="paged"),
            "paged+blocking": dict(cache="paged", prefill_chunk=0),
            "dense+chunked": dict(cache="dense"),
            "dense+blocking": dict(cache="dense", prefill_chunk=0),
            # device-resident decode A/B: K ticks per fused dispatch, async
            # pipelined serving (K=1 isolates the pipeline itself)
            "paged+chunked+mt1": dict(cache="paged", megatick=1,
                                      async_ticks=True),
            "paged+chunked+mt4": dict(cache="paged", megatick=4),
            "paged+chunked+mt16": dict(cache="paged", megatick=16),
        }
        if variants_filter is not None:
            variants = {k: v for k, v in variants.items()
                        if k in variants_filter}
        engines = {name: ServingEngine(model, params, sw, strategy="specee",
                                       mesh=mesh, **kw)
                   for name, kw in variants.items()}
        best = {name: {"tok_s": 0.0, "decode_tok_s": 0.0,
                       "admission_ms": float("inf"),
                       "tick_us": float("inf")}
                for name in variants}
        for name, se in engines.items():            # warmup (compile)
            _one_round(se, prompts, max_new)
        for _ in range(rounds):                     # interleaved min-timing
            for name, se in engines.items():
                r = _one_round(se, prompts, max_new)
                b = best[name]
                b["tok_s"] = max(b["tok_s"], r["tokens"] / r["wall_s"])
                b["decode_tok_s"] = max(
                    b["decode_tok_s"], r["decode_tokens"] / r["decode_s"])
                b["admission_ms"] = min(b["admission_ms"],
                                        r["admission_s"] * 1e3)
                b["tick_us"] = min(b["tick_us"], r["min_tick_s"] * 1e6)
                b["ticks"] = r["decode_ticks"]
                b["tokens"] = r["tokens"]
        for name in variants:
            se = engines[name]
            b = best[name]
            row = {"batch": B, "variant": name, "mesh": mesh_label,
                   "cache": se.cache_spec.kind,
                   "prefill_chunk": se.scheduler.chunk_tokens or 0,
                   "page_size": se.cache_spec.page_size,
                   "megatick": se.megatick,
                   "async_ticks": se.async_ticks,
                   # non-finite → None: a round can finish entirely inside
                   # the admission phase (e.g. --max-new 1), leaving no
                   # decode ticks — inf would serialize as invalid JSON
                   "decode_tok_s": round(b["decode_tok_s"], 2),
                   "admission_ms": round(b["admission_ms"], 2),
                   "tokens_per_s": round(b["tok_s"], 2),
                   "min_tick_us": (round(b["tick_us"], 1)
                                   if math.isfinite(b["tick_us"]) else None),
                   "ticks": b["ticks"],
                   "tokens": b["tokens"],
                   "backend": jax.default_backend()}
            rows.append(row)
            print(f"[bench_serving] B={B} {mesh_label} {name:18s} "
                  f"decode={row['decode_tok_s']:8.1f} tok/s  "
                  f"admit={row['admission_ms']:8.1f}ms  "
                  f"overall={row['tokens_per_s']:7.1f} tok/s  "
                  f"ticks={row['ticks']}")
    if write:
        _merge_rows(rows)
        print(f"[bench_serving] merged {len(rows)} rows into {_JSON}")
    return rows


def gate(threshold=0.20, rounds=2):
    """CI perf gate: re-measure the core serving variants at B=2 and diff
    ``decode_tok_s`` against the committed BENCH_serving.json. A fresh row
    below (1 - threshold) × its committed counterpart fails the gate; rows
    with no committed counterpart (or recorded on another backend) are
    skipped. Returns a process exit code."""
    committed = {(r["batch"], r["variant"], r["mesh"]): r
                 for r in _load_rows()}
    if not committed:
        print("[bench_serving] --gate: no committed BENCH_serving.json; "
              "skipping")
        return 0
    fresh = bench([2], rounds=rounds, max_new=32,
                  variants_filter=_CORE_VARIANTS, write=False)
    failures, checked = [], 0
    for r in fresh:
        ref = committed.get((r["batch"], r["variant"], r["mesh"]))
        if (ref is None or ref.get("backend") != r["backend"]
                or not ref.get("decode_tok_s")):
            continue
        checked += 1
        floor = (1.0 - threshold) * ref["decode_tok_s"]
        verdict = "OK" if r["decode_tok_s"] >= floor else "FAIL"
        print(f"[gate] B={r['batch']} {r['variant']:18s} "
              f"decode={r['decode_tok_s']:8.1f} tok/s vs committed "
              f"{ref['decode_tok_s']:8.1f} (floor {floor:8.1f}) {verdict}")
        if verdict == "FAIL":
            failures.append(r["variant"])
    if failures:
        print(f"[gate] FAIL: >{threshold:.0%} decode_tok_s regression in "
              f"{failures}")
        return 1
    print(f"[gate] OK: {checked} rows within {threshold:.0%} of committed")
    return 0


def tp_sweep(degrees, rounds, max_new):
    """Re-exec one child per TP degree: the forced-host-device flag must be
    in XLA_FLAGS before jax initializes its backends, which a fresh process
    guarantees and an in-process loop cannot."""
    for deg in degrees:
        cmd = [sys.executable, "-m", "benchmarks.bench_serving",
               "--tp", str(deg), "--batches", "2",
               "--rounds", str(rounds), "--max-new", str(max_new)]
        print(f"[bench_serving] tp-sweep: {' '.join(cmd)}")
        subprocess.run(cmd, check=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: run the core variants "
                         "under a (1, N) mesh on forced host devices and "
                         "label the rows mesh=tpN")
    ap.add_argument("--tp-sweep", action="store_true",
                    help="one child process per degree in {1, 2, 4}")
    ap.add_argument("--gate", action="store_true",
                    help="CI perf gate: fail on >--gate-threshold "
                         "decode_tok_s regression vs the committed rows")
    ap.add_argument("--gate-threshold", type=float, default=0.20)
    args = ap.parse_args()
    if args.tp > 1:
        # before any jax backend touch (module import alone doesn't init)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.tp}").strip()
    if args.gate:
        sys.exit(gate(threshold=args.gate_threshold))
    if args.tp_sweep:
        tp_sweep((1, 2, 4), min(args.rounds, 3), args.max_new)
        sys.exit(0)
    bench(args.batches, args.rounds, args.max_new, tp=args.tp)
