"""Paper Fig. 19 ablation: T1 (predictor everywhere) → +T2 (two-level
scheduling) → +T3 (tree speculative decoding with hyper-token mapping)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, get_bundle, token_batches, decode_run
from repro.api import TreeStrategy
from repro.core.tree import TreeSpec


def run(timer: Timer) -> None:
    b = get_bundle()
    prompts = token_batches(b.run, 1, B=1, S=16, seed=31)[0]
    new = 24
    dense = decode_run(b, "dense", prompts, new_tokens=new)
    t1 = decode_run(b, "specee_t1", prompts, new_tokens=new)
    t12 = decode_run(b, "specee", prompts, new_tokens=new)
    timer.add("ablation/dense", dense["seconds"] / new * 1e6, "1.00x")
    timer.add("ablation/T1", t1["seconds"] / new * 1e6,
              f"{dense['seconds']/t1['seconds']:.2f}x "
              f"avg_units={t1['avg_units']:.2f}")
    timer.add("ablation/T1+T2", t12["seconds"] / new * 1e6,
              f"{dense['seconds']/t12['seconds']:.2f}x "
              f"avg_units={t12['avg_units']:.2f}")

    # + T3: tree speculative decoding (tokens per TLM forward > 1)
    strat = TreeStrategy(tree=TreeSpec(depth=2, branch=3))
    m, params, sw = b.model, b.params, b.sw
    first, st = strat.init_state(m, params, sw, {"tokens": prompts}, 64)
    step = jax.jit(lambda p, s, stt: strat.step(m, p, s, stt))
    step(params, sw, st)  # compile
    emitted, ticks = 1, 0
    t0 = time.perf_counter()
    while emitted < new + 1 and ticks < 4 * new:
        res, st = step(params, sw, st)
        emitted += int(jnp.sum(res.counts))
        ticks += 1
    dt = time.perf_counter() - t0
    timer.add("ablation/T1+T2+T3", dt / max(emitted - 1, 1) * 1e6,
              f"{dense['seconds']/new/(dt/max(emitted-1,1)):.2f}x "
              f"tokens_per_forward={(emitted-1)/max(ticks,1):.2f}")


if __name__ == "__main__":
    t = Timer()
    run(t)
    t.emit()
