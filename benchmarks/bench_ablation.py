"""Paper Fig. 19 ablation: T1 (predictor everywhere) → +T2 (two-level
scheduling) → +T3 (tree speculative decoding with hyper-token mapping).

Also records the quant × exit-threshold Pareto sweep (``quant_pareto``):
weight-only fp32 / int8 / int4 LM-head+projection compression crossed with
exit thresholds, each point scoring decode speed, average exit depth, and
token agreement against the fp dense greedy reference — the speed/quality
frontier the compressed gate kernels trade along. Written into the
``quant_pareto`` row-group of ``BENCH_exit_gate.json``."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Timer, get_bundle, token_batches, decode_run,
                               merge_bench_json)
from repro.api import TreeStrategy
from repro.core.tree import TreeSpec

_GATE_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_exit_gate.json")


def run(timer: Timer) -> None:
    b = get_bundle()
    prompts = token_batches(b.run, 1, B=1, S=16, seed=31)[0]
    new = 24
    dense = decode_run(b, "dense", prompts, new_tokens=new)
    t1 = decode_run(b, "specee_t1", prompts, new_tokens=new)
    t12 = decode_run(b, "specee", prompts, new_tokens=new)
    timer.add("ablation/dense", dense["seconds"] / new * 1e6, "1.00x")
    timer.add("ablation/T1", t1["seconds"] / new * 1e6,
              f"{dense['seconds']/t1['seconds']:.2f}x "
              f"avg_units={t1['avg_units']:.2f}")
    timer.add("ablation/T1+T2", t12["seconds"] / new * 1e6,
              f"{dense['seconds']/t12['seconds']:.2f}x "
              f"avg_units={t12['avg_units']:.2f}")

    # + T3: tree speculative decoding (tokens per TLM forward > 1)
    strat = TreeStrategy(tree=TreeSpec(depth=2, branch=3))
    m, params, sw = b.model, b.params, b.sw
    first, st = strat.init_state(m, params, sw, {"tokens": prompts}, 64)
    step = jax.jit(lambda p, s, stt: strat.step(m, p, s, stt))
    step(params, sw, st)  # compile
    emitted, ticks = 1, 0
    t0 = time.perf_counter()
    while emitted < new + 1 and ticks < 4 * new:
        res, st = step(params, sw, st)
        emitted += int(jnp.sum(res.counts))
        ticks += 1
    dt = time.perf_counter() - t0
    timer.add("ablation/T1+T2+T3", dt / max(emitted - 1, 1) * 1e6,
              f"{dense['seconds']/new/(dt/max(emitted-1,1)):.2f}x "
              f"tokens_per_forward={(emitted-1)/max(ticks,1):.2f}")


def quant_pareto(timer: Timer, new: int = 16) -> list:
    """Quant level × exit threshold Pareto sweep.

    Every point decodes the same prompt through the AR-SpecEE strategy with
    a weight-only quantized bundle (None = fp32); quality is the per-token
    agreement with the fp32 dense greedy run (greedy decode is
    deterministic, so disagreement is exactly the compression + early-exit
    error surfacing in token space)."""
    b = get_bundle()
    prompts = token_batches(b.run, 1, B=1, S=16, seed=33)[0]
    ref = decode_run(b, "dense", prompts, new_tokens=new)["tokens"]
    rows = []
    for qspec in (None, "int8", "int4"):
        for thr in (0.3, 0.6, 0.9):
            r = decode_run(b, "specee", prompts, new_tokens=new,
                           threshold=thr, quant=qspec)
            match = float(np.mean(r["tokens"] == ref))
            name = qspec or "fp32"
            rows.append({"quant": name, "threshold": thr,
                         "tok_per_s": r["tok_per_s"],
                         "avg_units": r["avg_units"],
                         "avg_exit": r["avg_exit"],
                         "match_vs_dense_fp32": match,
                         "backend": jax.default_backend()})
            timer.add(f"quant_pareto/{name}_thr{thr}",
                      r["seconds"] / new * 1e6,
                      f"match={match:.3f} avg_units={r['avg_units']:.2f}")
    merge_bench_json(_GATE_JSON, "quant_pareto", rows)
    return rows


if __name__ == "__main__":
    t = Timer()
    run(t)
    quant_pareto(t)
    t.emit()
