"""Serving-layer fault-tolerance primitives (DESIGN.md §7).

``ServingEngine`` composes four recovery mechanisms out of the pieces here:

  * **structured faults** — ``ServingFault`` carries the site, the retry
    count, and the underlying cause, so an operator (or a test) can branch
    on *where* the stack failed instead of string-matching tracebacks.
    ``Preempted`` is the clean-shutdown variant: the engine checkpointed
    and the process should exit and be restarted with ``--restore``.
  * **victim selection** — ``VictimPolicy`` picks which live row to evict
    under pool pressure: least decode progress first (loses the least
    work), then fewest pages (cheapest to replay), then lowest row id
    (determinism). Requests evicted ``max_evictions`` times become
    protected — they are never picked again, which bounds total replay
    work and guarantees the engine makes forward progress instead of
    ping-ponging two requests through one page reservation forever.
  * **backoff** — ``Backoff`` yields the sleep schedule for megatick
    dispatch retries (exponential, capped attempts). Tests zero the base
    delay so retries are instant.
  * **fault log** — ``FaultEvent`` records every recovery action the
    engine took (retry, eviction, sync fallback, checkpoint, remesh), held
    in a ``FaultLog`` bounded ring so soak runs can't grow memory without
    bound, with a JSONL export for post-mortems. Tests assert not just that
    outputs are token-identical but that the intended degradation path
    actually ran.
  * **degraded-mode serving** — ``LoadShedPolicy`` bounds the admission
    queue once remeshed capacity drops below demand (reject at intake
    instead of queueing unboundedly), and ``PoolHealth`` is the
    ``ReplicaPool``'s machine-readable degradation surface.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Iterable, Iterator, List, Optional, Tuple, Union


class ServingFault(RuntimeError):
    """A serving failure the engine could not absorb.

    ``site`` is the named failure point ("dispatch", "finish_timeout",
    "nan_logits", "replay", "stall", ...), ``attempts`` the number of
    retries burned before surfacing, ``cause`` the underlying exception
    (also chained as ``__cause__`` where raised with ``raise ... from``).
    """

    def __init__(self, site: str, message: str, attempts: int = 0,
                 cause: Optional[BaseException] = None):
        super().__init__(f"[{site}] {message}")
        self.site = site
        self.attempts = attempts
        self.cause = cause


class Preempted(ServingFault):
    """SIGTERM drained + checkpointed: restart with ``--restore``.

    Not an error — the state the process is abandoning is fully captured in
    the checkpoint at ``path`` (tick ``step``)."""

    def __init__(self, step: int, path: str):
        super().__init__("sigterm",
                         f"preempted at tick {step}; checkpoint in {path} "
                         "(restart with --restore)")
        self.step = step
        self.path = path


@dataclass
class FaultEvent:
    """One recovery action taken by the serving engine."""
    site: str                   # which named site (or "evict" / "watchdog")
    tick: int                   # engine tick when it happened
    action: str                 # "retry" | "evict" | "sync_fallback" | ...
    detail: str = ""


class FaultLog:
    """Bounded ring of ``FaultEvent``s with a list-compatible surface.

    Engines append every recovery action here; the ring keeps only the last
    ``cap`` events (a long soak run under a flaky fleet would otherwise grow
    the log without bound) while ``total``/``dropped`` keep the true counts.
    Iteration, ``len``, indexing, and truthiness behave like the plain list
    the log used to be, so existing consumers (tests, the launcher's
    recovery print) read it unchanged. ``dump_jsonl`` writes the retained
    window as one JSON object per line — the machine-readable post-mortem
    trail behind ``launch/serve.py --fault-log``."""

    def __init__(self, cap: int = 256):
        if cap < 1:
            raise ValueError(f"FaultLog cap must be >= 1, got {cap}")
        self.cap = int(cap)
        self._events: Deque[FaultEvent] = deque(maxlen=self.cap)
        self.total = 0              # events ever appended

    @property
    def dropped(self) -> int:
        """Events evicted from the ring (oldest-first)."""
        return self.total - len(self._events)

    def append(self, event: FaultEvent) -> None:
        self._events.append(event)
        self.total += 1

    def extend(self, events: Iterable[FaultEvent]) -> None:
        for e in events:
            self.append(e)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def __bool__(self) -> bool:
        return bool(self._events)

    def __getitem__(self, i: Union[int, slice]):
        return list(self._events)[i]

    def dump_jsonl(self, path: str, source: str = "engine",
                   append: bool = False) -> int:
        """Write the retained events to ``path`` as JSONL. ``seq`` is the
        event's global index (dropped events leave a visible gap at the
        front); ``source`` labels the emitting engine/pool so one file can
        hold a whole fleet's trail. Returns the number of lines written."""
        base = self.dropped
        with open(path, "a" if append else "w") as f:
            for i, e in enumerate(self._events):
                f.write(json.dumps({
                    "seq": base + i, "source": source, "site": e.site,
                    "tick": e.tick, "action": e.action,
                    "detail": e.detail}) + "\n")
        return len(self._events)


@dataclass(frozen=True)
class LoadShedPolicy:
    """Queue bound for degraded-mode serving (DESIGN.md §10).

    When a remesh (or a replica death) drops pool capacity below demand,
    unbounded queueing just converts overload into unbounded latency — the
    pool instead REJECTS intake (``ServingFault(site="load_shed")``) once
    ``max_queue`` requests are already waiting. ``only_degraded`` (default)
    applies the bound only while the pool is degraded; set it False to bound
    the queue unconditionally. ``max_queue=None`` never sheds."""

    max_queue: Optional[int] = None
    only_degraded: bool = True

    def admits(self, queued: int, degraded: bool) -> bool:
        if self.max_queue is None:
            return True
        if self.only_degraded and not degraded:
            return True
        return queued < self.max_queue


@dataclass(frozen=True)
class PoolHealth:
    """``ReplicaPool.health``: the pool's degradation state, one snapshot.

    ``degraded`` is True when any replica is dead OR any live replica runs
    below its as-built TP degree (it remeshed after a device loss) — the
    signal ``LoadShedPolicy`` keys on."""

    replicas_total: int
    replicas_live: int
    tp_degrees: Tuple[int, ...]         # live replicas' CURRENT degrees
    built_tp_degrees: Tuple[int, ...]   # same replicas' as-built degrees
    queued: int
    degraded: bool


@dataclass(frozen=True)
class VictimInfo:
    """One eviction candidate, as the policy sees it."""
    row: int
    progress: int               # tokens emitted so far (work lost on evict)
    pages: int                  # KV pages held (work to replay)
    evictions: int              # times this request was already evicted


@dataclass(frozen=True)
class VictimPolicy:
    """LRU-by-progress, then fewest-pages, then row id (deterministic)."""

    max_evictions: int = 3      # then the request is protected

    def select(self, candidates: List[VictimInfo]) -> Optional[int]:
        eligible = [c for c in candidates if c.evictions < self.max_evictions]
        if not eligible:
            return None
        best = min(eligible, key=lambda c: (c.progress, c.pages, c.row))
        return best.row


@dataclass(frozen=True)
class Backoff:
    """Exponential retry schedule for megatick dispatch failures."""

    base_s: float = 0.05
    factor: float = 2.0
    max_attempts: int = 4

    def delays(self) -> Iterator[float]:
        """Sleep to apply AFTER each failed attempt (the first attempt is
        free; ``max_attempts`` total attempts are made)."""
        for i in range(self.max_attempts - 1):
            yield self.base_s * (self.factor ** i)

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)
