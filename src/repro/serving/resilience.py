"""Serving-layer fault-tolerance primitives (DESIGN.md §7).

``ServingEngine`` composes four recovery mechanisms out of the pieces here:

  * **structured faults** — ``ServingFault`` carries the site, the retry
    count, and the underlying cause, so an operator (or a test) can branch
    on *where* the stack failed instead of string-matching tracebacks.
    ``Preempted`` is the clean-shutdown variant: the engine checkpointed
    and the process should exit and be restarted with ``--restore``.
  * **victim selection** — ``VictimPolicy`` picks which live row to evict
    under pool pressure: least decode progress first (loses the least
    work), then fewest pages (cheapest to replay), then lowest row id
    (determinism). Requests evicted ``max_evictions`` times become
    protected — they are never picked again, which bounds total replay
    work and guarantees the engine makes forward progress instead of
    ping-ponging two requests through one page reservation forever.
  * **backoff** — ``Backoff`` yields the sleep schedule for megatick
    dispatch retries (exponential, capped attempts). Tests zero the base
    delay so retries are instant.
  * **fault log** — ``FaultEvent`` records every recovery action the
    engine took (retry, eviction, sync fallback, checkpoint), so the
    acceptance tests can assert not just that outputs are token-identical
    but that the intended degradation path actually ran.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, List, Optional


class ServingFault(RuntimeError):
    """A serving failure the engine could not absorb.

    ``site`` is the named failure point ("dispatch", "finish_timeout",
    "nan_logits", "replay", "stall", ...), ``attempts`` the number of
    retries burned before surfacing, ``cause`` the underlying exception
    (also chained as ``__cause__`` where raised with ``raise ... from``).
    """

    def __init__(self, site: str, message: str, attempts: int = 0,
                 cause: Optional[BaseException] = None):
        super().__init__(f"[{site}] {message}")
        self.site = site
        self.attempts = attempts
        self.cause = cause


class Preempted(ServingFault):
    """SIGTERM drained + checkpointed: restart with ``--restore``.

    Not an error — the state the process is abandoning is fully captured in
    the checkpoint at ``path`` (tick ``step``)."""

    def __init__(self, step: int, path: str):
        super().__init__("sigterm",
                         f"preempted at tick {step}; checkpoint in {path} "
                         "(restart with --restore)")
        self.step = step
        self.path = path


@dataclass
class FaultEvent:
    """One recovery action taken by the serving engine."""
    site: str                   # which named site (or "evict" / "watchdog")
    tick: int                   # engine tick when it happened
    action: str                 # "retry" | "evict" | "sync_fallback" | ...
    detail: str = ""


@dataclass(frozen=True)
class VictimInfo:
    """One eviction candidate, as the policy sees it."""
    row: int
    progress: int               # tokens emitted so far (work lost on evict)
    pages: int                  # KV pages held (work to replay)
    evictions: int              # times this request was already evicted


@dataclass(frozen=True)
class VictimPolicy:
    """LRU-by-progress, then fewest-pages, then row id (deterministic)."""

    max_evictions: int = 3      # then the request is protected

    def select(self, candidates: List[VictimInfo]) -> Optional[int]:
        eligible = [c for c in candidates if c.evictions < self.max_evictions]
        if not eligible:
            return None
        best = min(eligible, key=lambda c: (c.progress, c.pages, c.row))
        return best.row


@dataclass(frozen=True)
class Backoff:
    """Exponential retry schedule for megatick dispatch failures."""

    base_s: float = 0.05
    factor: float = 2.0
    max_attempts: int = 4

    def delays(self) -> Iterator[float]:
        """Sleep to apply AFTER each failed attempt (the first attempt is
        free; ``max_attempts`` total attempts are made)."""
        for i in range(self.max_attempts - 1):
            yield self.base_s * (self.factor ** i)

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)
