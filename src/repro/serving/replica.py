"""Data-parallel replica pool — N ``ServingEngine``s behind one queue.

The sharding story (DESIGN.md §9) splits cleanly in two: WITHIN a replica,
tensor parallelism over the mesh's 'model' axis (``ServingEngine(mesh=...)``)
keeps decode token-identical to single-device; ACROSS replicas, this pool
provides throughput scaling with no cross-replica collective at all —
replicas share weights by construction (same params pytree, one mesh each)
and requests are whole units, so the only shared state is the admission
queue.

Fault tolerance composes with PR 6's recompute replay: when a replica dies
(``Preempted`` / ``ServingFault`` out of its ``step``) or is evicted as a
straggler (``runtime.fault.StragglerMonitor`` over per-replica step times),
its in-flight requests requeue onto survivors via ``ServingEngine.adopt`` —
the survivor re-prefills each request and *verifies* the tokens the dead
replica already emitted against the record (decode is deterministic and the
replicas share weights), so a migration costs recompute but never changes
output. ``plan_remesh`` annotates each kill with the post-failure mesh the
fleet could rebuild to.

Elastic degraded mode (DESIGN.md §10): a ``device_lost`` fault inside a
replica's engine REMESHES it in place — the engine drains, consults
``plan_replica_remesh`` for the largest TP degree over its surviving
devices, rebuilds, and replays its own requests with verification; the pool
just observes the degree drop and records it. Only when no factorization
remains does the engine's ``ServingFault(site="device_lost")`` fall back to
kill-and-requeue above. Because a degraded pool serves below its built
capacity, requests carry optional ``deadline_ticks`` (expired requests are
SHED with a structured ``ServingFault(site="deadline")`` instead of waiting
forever) and a ``LoadShedPolicy`` can bound the intake queue (rejection via
``ServingFault(site="load_shed")``); ``pool.health`` surfaces the
degradation state machine-readably.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.fault import StragglerMonitor, plan_remesh
from repro.serving.resilience import (FaultEvent, FaultLog, LoadShedPolicy,
                                      PoolHealth, Preempted, ServingFault)
from repro.serving.server import Request, ServingEngine


@dataclass
class PoolRequest:
    """One request as the pool sees it.

    ``handle`` is the engine-level ``Request`` on the owning replica; the
    pool's own ``output``/stats fields are the migration-safe record —
    snapshotted from the handle when the owner dies, fed back as the replay
    prefix (``adopt(recorded=...)``) on reassignment."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    replica: Optional[int] = None
    handle: Optional[Request] = None
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    accept_lens: List[int] = field(default_factory=list)
    done: bool = False
    migrations: int = 0
    # degraded-mode serving: ``deadline_ticks`` pool ticks after
    # ``submitted_tick`` an unfinished request is SHED (``failed`` set,
    # ``fault`` carries the structured ServingFault) instead of queueing
    # forever against capacity the pool no longer has
    deadline_ticks: Optional[int] = None
    submitted_tick: int = 0
    failed: bool = False
    fault: Optional[ServingFault] = None


class ReplicaPool:
    """Shared admission queue over N independent ``ServingEngine`` replicas.

    ``step()`` drives every live replica one engine tick, timing each for
    the straggler monitor; replica death (or straggler eviction) requeues
    its unfinished requests onto survivors with verified replay. Killing
    the LAST live replica raises — there is nowhere left to migrate.
    """

    def __init__(self, replicas: Sequence[ServingEngine],
                 monitor: Optional[StragglerMonitor] = None,
                 evict_stragglers: bool = True,
                 shed: Optional[LoadShedPolicy] = None,
                 fault_log_cap: int = 256):
        if not replicas:
            raise ValueError("ReplicaPool needs at least one replica")
        self.replicas: List[ServingEngine] = list(replicas)
        self.alive: List[bool] = [True] * len(self.replicas)
        self.monitor = (monitor if monitor is not None
                        else StragglerMonitor())
        self.evict_stragglers = bool(evict_stragglers)
        self.shed = shed if shed is not None else LoadShedPolicy()
        self.queue: List[PoolRequest] = []
        self.requests: Dict[int, PoolRequest] = {}
        self.completed: List[PoolRequest] = []
        self.failed: List[PoolRequest] = []     # deadline-shed requests
        self.fault_log = FaultLog(cap=fault_log_cap)
        self._next_uid = 0
        self._tick = 0
        # degradation tracking: as-built vs current per-replica TP degree
        # (an in-engine remesh drops the current one), plus the last health
        # verdict so state TRANSITIONS land in the fault log exactly once
        self._built_tp = tuple(e.tp_degree for e in self.replicas)
        self._tp_now = list(self._built_tp)
        self._was_degraded = False

    # ----- intake / placement -----
    def submit(self, prompt, max_new_tokens: int = 32,
               eos_token: Optional[int] = None,
               deadline_ticks: Optional[int] = None) -> PoolRequest:
        """Queue a request. ``deadline_ticks``: pool ticks this request may
        wait+run before being shed. Raises ``ServingFault(site="load_shed")``
        when the shed policy's queue bound rejects the intake (degraded pool
        at capacity — the caller should retry later or elsewhere)."""
        if not self.shed.admits(len(self.queue), self.degraded):
            self.fault_log.append(FaultEvent(
                site="load_shed", tick=self._tick, action="reject",
                detail=f"queue={len(self.queue)} >= "
                       f"{self.shed.max_queue} (degraded={self.degraded})"))
            raise ServingFault(
                "load_shed",
                f"intake rejected: {len(self.queue)} queued >= bound "
                f"{self.shed.max_queue} while degraded")
        pr = PoolRequest(uid=self._next_uid,
                         prompt=np.asarray(prompt, np.int32),
                         max_new_tokens=max_new_tokens, eos_token=eos_token,
                         deadline_ticks=deadline_ticks,
                         submitted_tick=self._tick)
        self._next_uid += 1
        self.requests[pr.uid] = pr
        self.queue.append(pr)
        return pr

    def live_replicas(self) -> List[int]:
        return [i for i, a in enumerate(self.alive) if a]

    # ----- health / degradation -----
    @property
    def health(self) -> PoolHealth:
        live = self.live_replicas()
        tp_now = tuple(self._tp_now[i] for i in live)
        built = tuple(self._built_tp[i] for i in live)
        return PoolHealth(
            replicas_total=len(self.replicas), replicas_live=len(live),
            tp_degrees=tp_now, built_tp_degrees=built,
            queued=len(self.queue),
            degraded=(len(live) < len(self.replicas)
                      or any(n < b for n, b in zip(tp_now, built))))

    @property
    def degraded(self) -> bool:
        return self.health.degraded

    def _note_health(self) -> None:
        """Log degradation-state TRANSITIONS (not every tick's state)."""
        h = self.health
        if h.degraded != self._was_degraded:
            self._was_degraded = h.degraded
            self.fault_log.append(FaultEvent(
                site="health", tick=self._tick,
                action="degraded" if h.degraded else "recovered",
                detail=f"live={h.replicas_live}/{h.replicas_total} "
                       f"tp={list(h.tp_degrees)} built="
                       f"{list(h.built_tp_degrees)} queued={h.queued}"))

    def _note_remeshes(self) -> None:
        """Record per-replica TP drops (an engine remeshed inside its own
        ``step``) at pool level — the FaultEvent(action="remesh") the
        acceptance tests look for rides on the engine's own log too."""
        for i in self.live_replicas():
            now = self.replicas[i].tp_degree
            if now < self._tp_now[i]:
                self.fault_log.append(FaultEvent(
                    site="device_lost", tick=self._tick, action="remesh",
                    detail=f"replica={i} tp {self._tp_now[i]}->{now} "
                           f"(built {self._built_tp[i]})"))
                self._tp_now[i] = now

    def _capacity(self, i: int) -> int:
        """Free slots minus admission backlog — the placement score."""
        eng = self.replicas[i]
        free = sum(1 for s in eng.slots if s is None)
        backlog = len(eng.scheduler.queued) + len(eng.scheduler.admitting)
        return free - backlog

    def _assign(self) -> None:
        """Drain the shared queue onto the emptiest live replicas. A
        re-queued (migrated) request carries its recorded tokens as the
        replay prefix — ``adopt`` with an empty record is a plain submit."""
        live = self.live_replicas()
        if not live:
            return
        while self.queue:
            pr = self.queue.pop(0)
            i = max(live, key=self._capacity)
            pr.replica = i
            pr.handle = self.replicas[i].adopt(
                pr.prompt, max_new_tokens=pr.max_new_tokens,
                eos_token=pr.eos_token, recorded=pr.output,
                stats=(pr.exit_points, pr.accept_lens))

    # ----- failure / migration -----
    def _snapshot_handle(self, pr: PoolRequest) -> None:
        h = pr.handle
        if h is None:
            return
        pr.output = [int(t) for t in h.output]
        pr.exit_points = [int(x) for x in h.exit_points]
        pr.accept_lens = [int(x) for x in h.accept_lens]

    def _tp_degree(self) -> int:
        return self.replicas[0].tp_degree

    def kill_replica(self, i: int, reason: str = "killed",
                     detail: str = "") -> None:
        """Mark replica ``i`` dead and requeue its unfinished requests.

        Each migrated request keeps everything the dead replica emitted
        (snapshotted off its handle) and will replay-verify those tokens on
        the survivor. Requests whose handle already finished complete
        normally. Raises when the pool's last live replica dies."""
        if not self.alive[i]:
            return
        self.alive[i] = False
        requeued = 0
        for pr in self.requests.values():
            if pr.done or pr.replica != i:
                continue
            self._snapshot_handle(pr)
            if pr.handle is not None and pr.handle.done:
                pr.done = True
                self.completed.append(pr)
                continue
            pr.replica = None
            pr.handle = None
            pr.migrations += 1
            self.queue.append(pr)
            requeued += 1
        try:
            self.replicas[i].close()
        except Exception:
            pass
        tp = self._tp_degree()
        plan = plan_remesh(len(self.live_replicas()) * tp, tp)
        self.fault_log.append(FaultEvent(
            site=reason, tick=self._tick, action="kill_replica",
            detail=f"replica={i} requeued={requeued} remesh={plan}; "
                   f"{detail}"))
        if not any(self.alive):
            raise ServingFault(
                "replica_pool",
                f"last replica ({i}) died ({reason}); "
                f"{requeued} requests stranded")

    def _maybe_evict_straggler(self) -> None:
        """Evict the slowest monitor-flagged live replica (never the last):
        its requests migrate to faster survivors instead of pacing the whole
        pool at the straggler's EWMA."""
        if not self.evict_stragglers:
            return
        live = self.live_replicas()
        if len(live) < 2:
            return
        flagged = [h for h in self.monitor.stragglers()
                   if h in live]
        if not flagged:
            return
        worst = max(flagged, key=lambda h: self.monitor.hosts[h].ewma)
        self.kill_replica(worst, reason="straggler",
                          detail=f"ewma={self.monitor.hosts[worst].ewma:.4f}")

    # ----- deadlines (degraded-mode load shedding) -----
    def _shed_expired(self, finished: List["PoolRequest"]) -> None:
        """Shed unfinished requests past their deadline: queued ones drop
        out of the queue, slotted ones cancel on their engine (the engine
        drains its megatick first — a request the drain FINISHES made the
        deadline after all and completes normally). A shed request is
        terminal: ``failed`` with a structured ServingFault, never requeued."""
        for pr in list(self.requests.values()):
            if (pr.done or pr.failed or pr.deadline_ticks is None
                    or self._tick - pr.submitted_tick < pr.deadline_ticks):
                continue
            if pr in self.queue:
                self.queue.remove(pr)
            elif pr.handle is not None and pr.replica is not None \
                    and self.alive[pr.replica]:
                self.replicas[pr.replica].cancel(pr.handle.uid)
                if pr.handle.done:      # drained over the finish line
                    self._snapshot_handle(pr)
                    pr.done = True
                    self.completed.append(pr)
                    finished.append(pr)
                    continue
                self._snapshot_handle(pr)
            pr.failed = True
            pr.done = True
            pr.fault = ServingFault(
                "deadline",
                f"uid={pr.uid} shed after {self._tick - pr.submitted_tick} "
                f"ticks (deadline {pr.deadline_ticks}); "
                f"progress={len(pr.output)}/{pr.max_new_tokens}")
            pr.replica = None
            pr.handle = None
            self.failed.append(pr)
            self.fault_log.append(FaultEvent(
                site="deadline", tick=self._tick, action="shed",
                detail=f"uid={pr.uid} progress={len(pr.output)} "
                       f"deadline={pr.deadline_ticks}"))

    # ----- drive -----
    def step(self) -> List[PoolRequest]:
        """One pool tick: place queued work, step every live busy replica
        (timed for the straggler monitor; death → migrate), collect
        completions, then straggler eviction. Returns the requests that
        completed this call."""
        self._tick += 1
        self._assign()
        for i in list(self.live_replicas()):
            eng = self.replicas[i]
            if not eng.busy:
                continue
            t0 = time.monotonic()
            try:
                eng.step()
            except Preempted as err:
                self.kill_replica(i, reason="preempted", detail=str(err))
                continue
            except ServingFault as err:
                self.kill_replica(i, reason=err.site, detail=str(err))
                continue
            self.monitor.record(i, time.monotonic() - t0)
        self._note_remeshes()
        finished: List[PoolRequest] = []
        for pr in self.requests.values():
            if pr.done or pr.handle is None or not pr.handle.done:
                continue
            self._snapshot_handle(pr)
            pr.done = True
            self.completed.append(pr)
            finished.append(pr)
        self._shed_expired(finished)
        self._maybe_evict_straggler()
        self._note_health()
        self._assign()          # migrated work lands without an extra tick
        return finished

    @property
    def busy(self) -> bool:
        return (bool(self.queue)
                or any(not pr.done for pr in self.requests.values()))

    def run_to_completion(self, max_ticks: int = 10_000
                          ) -> List[PoolRequest]:
        done: List[PoolRequest] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.busy:
                return done
        raise ServingFault(
            "stall",
            f"pool still busy after {max_ticks} ticks: "
            f"queued={len(self.queue)} "
            f"live={len(self.live_replicas())}/{len(self.replicas)}")

    def close(self) -> None:
        for i in self.live_replicas():
            try:
                self.replicas[i].close()
            except Exception:
                pass
