"""Continuous-batching serving engine with SpecEE as the decode fast path.

vLLM-style slot model adapted to JAX's static shapes:
  * ``max_batch`` slots share one batched DecodeState (caches are (B, S, …));
  * arriving requests are prefilled individually (batch-1 prefill — the
    expensive, variable-length op) and their rows are *inserted* into the
    batched state; per-row cache lengths make ragged prompts first-class;
  * every engine tick runs ONE batched ``ar_decode_step`` (SpecEE) or dense
    step for all live slots; finished rows (EOS / max_new) retire and free
    their slot — exactly the iteration-level scheduling of Orca/vLLM;
  * inactive slots are masked; their compute is wasted but bounded (the
    standard TPU static-batch trade-off; see DESIGN.md §3).

This engine is the PC/cloud *logic* deliverable; the multi-pod path lowers
the same ``ar_decode_step`` through pjit (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core import engine as eng
from repro.core import scheduler as sched_lib
from repro.models.model import Model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    done: bool = False


def _insert_row(big, small, row: int, batch: int):
    """Insert batch-1 pytree ``small`` as row ``row`` of batched ``big``."""
    def one(b, s):
        axis = None
        for i, (db, ds) in enumerate(zip(b.shape, s.shape)):
            if db == batch and ds == 1:
                axis = i
                break
        if axis is None and b.shape == s.shape:
            return b  # batch-independent leaf (e.g. PRNG key): keep
        assert axis is not None, f"no batch axis: {b.shape} vs {s.shape}"
        idx = [slice(None)] * b.ndim
        idx[axis] = row
        src = jnp.squeeze(s, axis=axis)
        return b.at[tuple(idx)].set(src.astype(b.dtype))
    return jax.tree_util.tree_map(one, big, small)


class ServingEngine:
    def __init__(self, model: Model, params, sw: eng.SpecEEWeights,
                 specee: bool = True, prng_seed: int = 0):
        self.model = model
        self.params = params
        self.sw = sw
        self.specee = specee and model.run.specee.enabled
        self.serve_cfg = model.run.serve
        B = self.serve_cfg.max_batch
        S = self.serve_cfg.max_seq_len
        self.B, self.S = B, S
        self.slots: List[Optional[Request]] = [None] * B
        self.remaining = np.zeros(B, np.int64)
        self.pending: List[Request] = []
        self._state = self._empty_state()
        self._active = np.zeros(B, bool)
        self._step_jit = jax.jit(self._step_fn)
        self._uid = itertools.count()

    # ----- state plumbing -----
    def _empty_state(self) -> eng.DecodeState:
        m, B, S = self.model, self.B, self.S
        from repro.core import draft as draft_lib
        from repro.models.common import dtype_of
        cache = m.empty_cache(B, S)
        dcache = draft_lib.draft_cache(m.cfg, B, S, dtype_of(m.cfg.dtype))
        return eng.DecodeState(
            cache=cache, draft_cache=dcache,
            sched=sched_lib.init_state(B, m.run.specee),
            last_token=jnp.zeros((B,), jnp.int32),
            h_last=jnp.zeros((B, m.cfg.d_model),
                             dtype_of(m.cfg.dtype)),
            prng=jax.random.PRNGKey(0))

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> Request:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        self.pending.append(req)
        return req

    # ----- admission: batch-1 prefill, insert into slot -----
    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            tokens = jnp.asarray(req.prompt[None, :])       # (1, T)
            first, st1 = eng.init_decode_state(
                self.model, self.params, self.sw, {"tokens": tokens},
                max_seq=self.S)
            self._state = eng.DecodeState(*[
                _insert_row(big, small, slot, self.B)
                for big, small in zip(self._state, st1)])
            req.output.append(int(first[0]))
            self.slots[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1
            self._active[slot] = True

    # ----- one batched decode tick -----
    def _step_fn(self, params, sw, state):
        if self.specee:
            return eng.ar_decode_step(self.model, params, sw, state)
        return eng.dense_decode_step(self.model, params, sw, state)

    def step(self) -> List[Request]:
        """Admit, decode one token for all live slots, retire finished.
        Returns the list of requests completed this tick."""
        self._admit()
        if not self._active.any():
            return []
        token, new_state, info = self._step_jit(self.params, self.sw,
                                                self._state)
        self._state = new_state
        token_h = np.asarray(token)
        exit_h = np.asarray(info.exit_point)
        finished: List[Request] = []
        for slot in range(self.B):
            req = self.slots[slot]
            if req is None or not self._active[slot]:
                continue
            tok = int(token_h[slot])
            req.output.append(tok)
            req.exit_points.append(int(exit_h[slot]))
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or (req.eos_token is not None
                                             and tok == req.eos_token):
                req.done = True
                finished.append(req)
                self.slots[slot] = None
                self._active[slot] = False
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.pending and not self._active.any():
                break
        return done
