"""Continuous-batching serving engine — a slot loop over ``DecodeSession``
with managed cache memory and scheduled admission.

vLLM-style slot model adapted to JAX's static shapes:
  * ``max_batch`` slots share one batched ``DecodeSession`` whose memory is
    owned by a ``KVCacheManager`` (``repro.api.cache``): **paged KV** by
    default (``ServeConfig.page_size`` pages + per-row page tables, free-page
    admission control), with the slot-masked dense layout available as the
    bit-identical reference (``cache="dense"``);
  * admission runs through the ``ChunkedPrefillScheduler``
    (``repro.api.scheduler``): prompts split into ``ServeConfig.
    prefill_chunk``-token chunks interleaved with decode ticks
    (Sarathi-style) — a live batch is never stalled more than one chunk
    budget per tick. ``prefill_chunk=0`` restores blocking whole-prompt
    admission;
  * every engine tick runs ONE batched strategy step for all live slots —
    dense, AR-SpecEE, or tree speculative decoding behind the same
    ``StepResult`` surface; finished rows retire *and compact*
    (``session.retire_row``): their pages return to the pool and their
    logical length drops to zero, so long-idle slots stop paying attention
    span — exactly the iteration-level scheduling of Orca/vLLM;
  * inactive slots are masked; their compute is wasted but bounded (the
    standard TPU static-batch trade-off; see DESIGN.md §3), and after
    compaction an idle slot's attention span is ~zero rather than its stale
    context length.

Serve-path adoption (ROADMAP): the engine defaults the fused exit-gate
pipeline ON (``ModelFlags.exit_gate_kernel``) — pass ``fused_gate=False`` to
pin the reference path. Sampling modes come from ``run.serve`` (greedy /
temperature) on the dense strategy; ``prng_seed`` seeds the session's PRNG
stream so sampled runs are reproducible per seed.

Device-resident multi-tick decode (PR 5, DESIGN.md §6): ``megatick=K`` folds
K decode ticks into one fused ``lax.while_loop`` dispatch (budget/EOS/done
accounting in the jitted carry — host sync once per K tokens instead of once
per token), and ``async_ticks`` (default ON when K > 1) pipelines the loop:
``step()`` dispatches megatick N+1 BEFORE blocking on megatick N's results,
so host-side detokenization, retirement, and chunked admission overlap
device compute. The pipeline is correct because the done mask rides in the
device carry (a megatick dispatched against rows that just finished runs
zero device ticks), at the cost of results and admissions lagging one
``step()`` call — ``run_to_completion`` drains the in-flight handle.

This engine is the PC/cloud *logic* deliverable; the multi-pod path lowers
the same strategy step through pjit (launch/serve.py, launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.api import (CacheSpec, DecodeStrategy, DenseStrategy, Engine,
                       get_strategy)
from repro.api.scheduler import ChunkedPrefillScheduler
from repro.models.model import Model, build_model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    accept_lens: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, sw=None, specee: bool = True,
                 strategy: Union[str, DecodeStrategy, None] = None,
                 prng_seed: int = 0, fused_gate: bool = True,
                 cache: Union[None, str, CacheSpec] = "paged",
                 page_size: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 megatick: int = 1,
                 async_ticks: Optional[bool] = None):
        spec = CacheSpec.resolve(cache, model.run.serve)
        if page_size is not None:
            # the override obeys the same rule ServeConfig validates at
            # construction (pages tile the cache exactly)
            if page_size <= 0 or model.run.serve.max_seq_len % page_size:
                raise ValueError(
                    f"page_size ({page_size}) must be > 0 and divide "
                    f"max_seq_len ({model.run.serve.max_seq_len})")
            spec = dataclasses.replace(spec, page_size=page_size)
        self.cache_spec = spec
        flags = model.flags
        if bool(fused_gate) != getattr(flags, "exit_gate_kernel", False):
            flags = dataclasses.replace(flags,
                                        exit_gate_kernel=bool(fused_gate))
        # paged serving pairs with the page-table-aware decode kernel on real
        # hardware; off-TPU the kernel would run in interpret mode, so the
        # XLA gather path stays (same tokens — the kernel is a perf variant)
        if (spec.kind == "paged" and not flags.decode_kernel
                and jax.default_backend() == "tpu"):
            flags = dataclasses.replace(flags, decode_kernel=True)
        if flags is not model.flags:
            model = build_model(model.run, flags)
        self.model = model
        self.serve_cfg = model.run.serve
        if strategy is None:
            if specee and model.run.specee.enabled:
                strategy = "specee"
            elif self.serve_cfg.greedy:
                strategy = "dense"
            else:
                strategy = DenseStrategy(
                    temperature=self.serve_cfg.temperature)
        self.strategy = get_strategy(strategy)
        self.engine = Engine.create(model, params, sw=sw,
                                    strategy=self.strategy)
        B = self.serve_cfg.max_batch
        S = self.serve_cfg.max_seq_len
        self.B, self.S = B, S
        self.session = self.engine.new_session(batch=B, max_seq=S,
                                               prng_seed=prng_seed,
                                               cache=self.cache_spec)
        chunk = (self.serve_cfg.prefill_chunk if prefill_chunk is None
                 else prefill_chunk)
        self.scheduler = ChunkedPrefillScheduler(
            self.session, chunk_tokens=chunk or None)
        self.slots: List[Optional[Request]] = [None] * B
        self._inflight: Dict[int, Request] = {}
        self._uid = itertools.count()
        if megatick < 1:
            raise ValueError(f"megatick must be >= 1, got {megatick}")
        self.megatick = int(megatick)
        # pipelined ticks default ON whenever megaticks are on: the whole
        # point of folding K ticks into one dispatch is to overlap the
        # host work with device compute
        self.async_ticks = (self.megatick > 1 if async_ticks is None
                            else bool(async_ticks))
        self._handle = None             # in-flight async megatick

    # ----- request intake -----
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> Request:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        self._inflight[req.uid] = req
        self.scheduler.submit(req.uid, req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              eos_token=req.eos_token)
        return req

    @property
    def pending(self) -> List[Request]:
        """Requests not yet slotted: queued + the in-flight chunked
        admission (back-compat view — pre-PR3 a request stayed in
        ``pending`` until it occupied a slot)."""
        return [self._inflight[uid] for uid in
                self.scheduler.admitting + self.scheduler.queued]

    def _retire(self, row: int, req: Request,
                finished: List[Request]) -> None:
        req.done = True
        finished.append(req)
        self.slots[row] = None
        self.session.retire_row(row)    # compaction: free pages, zero span

    def _collect(self, res, slots: List[Optional[Request]],
                 finished: List[Request]) -> None:
        """Fold one (possibly multi-tick) StepResult into the requests that
        occupied the slots WHEN THE MEGATICK WAS DISPATCHED: detokenize,
        per-tick exit stats, retire + compact. The snapshot matters in the
        async pipeline — a slot can be re-admitted between a megatick's
        dispatch and its finish, and the old result must not be attributed
        to (or retire) the new occupant. A request already retired by an
        earlier finish is skipped (later megaticks report it done again but
        emit nothing for it — the device done-mask guarantees counts 0)."""
        for slot in range(self.B):
            req = slots[slot]
            if req is None or req.done:
                continue
            req.output.extend(res.row_tokens(slot))
            req.exit_points.extend(res.row_exit_points(slot))
            req.accept_lens.extend(res.row_accept_lens(slot))
            if res.done[slot]:
                # req not done => its slot has not been re-admitted (slots
                # only free at retirement), so slots[slot] is still req
                self._retire(slot, req, finished)

    def _dispatch(self):
        """Dispatch one megatick (plus the slot snapshot its results will be
        attributed to) if any row may still be live. The host view can trail
        the device by one in-flight megatick, but only toward liveness (rows
        never un-finish between admissions), so a stale dispatch at worst
        runs zero device ticks."""
        if np.any(self.session.live_rows()):
            return self.session.step_async(self.megatick), list(self.slots)
        return None

    # ----- one batched engine tick -----
    def step(self) -> List[Request]:
        """Scheduled admission (≤ one prefill chunk while decode is live),
        one strategy megatick for all live slots, retire + compact finished.
        Returns the list of requests completed this call.

        With ``async_ticks`` the call is one pipeline stage: megatick N+1 is
        dispatched BEFORE megatick N's results are read, so the host work
        below (detokenization, retirement, chunked admission) overlaps device
        compute; results consequently arrive one call later than they did on
        the blocking path."""
        finished: List[Request] = []
        prev, self._handle = self._handle, None
        if prev is not None:
            # overlap: next megatick goes out before we block on this one
            self._handle = self._dispatch()
            handle, slots_at_dispatch = prev
            self._collect(self.session.finish_step(handle),
                          slots_at_dispatch, finished)
        live = bool(np.any(self.session.live_rows()))
        free = [s for s in range(self.B) if self.slots[s] is None]
        for ev in self.scheduler.tick(free, live_decode=live):
            req = self._inflight.pop(ev.uid)
            if req.max_new_tokens > 0:
                req.output.append(ev.first_token)
            if self.session.row_done(ev.row):
                self._retire(ev.row, req, finished)
            else:
                self.slots[ev.row] = req
        if self._handle is None:
            if not np.any(self.session.live_rows()):
                return finished
            if self.async_ticks:
                self._handle = self._dispatch()
            else:
                self._collect(self.session.step(num_ticks=self.megatick),
                              self.slots, finished)
        return finished

    @property
    def in_flight(self) -> bool:
        """An async megatick is dispatched but its results are unread."""
        return self._handle is not None

    @property
    def busy(self) -> bool:
        """Work outstanding: queued/in-flight admission, live decode rows,
        or an in-flight async megatick awaiting its results."""
        return (self._handle is not None or self.scheduler.has_work()
                or bool(np.any(self.session.live_rows())))

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.busy:
                break
        return done
