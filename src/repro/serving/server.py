"""Continuous-batching serving engine — a thin slot loop over ``DecodeSession``.

vLLM-style slot model adapted to JAX's static shapes:
  * ``max_batch`` slots share one batched ``DecodeSession``;
  * arriving requests are prefilled individually (batch-1 prefill — the
    expensive, variable-length op) and *inserted* into a free row
    (``session.prefill_row``); per-row cache lengths make ragged prompts
    first-class;
  * every engine tick runs ONE batched strategy step for all live slots —
    dense, AR-SpecEE, or tree speculative decoding behind the same
    ``StepResult`` surface (tree serving emits up to depth+1 tokens per
    tick); finished rows (EOS / max_new, tracked by the session) retire and
    free their slot — exactly the iteration-level scheduling of Orca/vLLM;
  * inactive slots are masked; their compute is wasted but bounded (the
    standard TPU static-batch trade-off; see DESIGN.md §3).

Serve-path adoption (ROADMAP): the engine defaults the fused exit-gate
pipeline ON (``ModelFlags.exit_gate_kernel``) — pass ``fused_gate=False`` to
pin the reference path. Sampling modes come from ``run.serve`` (greedy /
temperature) on the dense strategy; ``prng_seed`` seeds the session's PRNG
stream so sampled runs are reproducible per seed.

This engine is the PC/cloud *logic* deliverable; the multi-pod path lowers
the same strategy step through pjit (launch/serve.py, launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Union

import numpy as np

from repro.api import DecodeStrategy, DenseStrategy, Engine, get_strategy
from repro.models.model import Model, build_model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    accept_lens: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, sw=None, specee: bool = True,
                 strategy: Union[str, DecodeStrategy, None] = None,
                 prng_seed: int = 0, fused_gate: bool = True):
        if bool(fused_gate) != getattr(model.flags, "exit_gate_kernel", False):
            model = build_model(model.run, dataclasses.replace(
                model.flags, exit_gate_kernel=bool(fused_gate)))
        self.model = model
        self.serve_cfg = model.run.serve
        if strategy is None:
            if specee and model.run.specee.enabled:
                strategy = "specee"
            elif self.serve_cfg.greedy:
                strategy = "dense"
            else:
                strategy = DenseStrategy(
                    temperature=self.serve_cfg.temperature)
        self.strategy = get_strategy(strategy)
        self.engine = Engine.create(model, params, sw=sw,
                                    strategy=self.strategy)
        B = self.serve_cfg.max_batch
        S = self.serve_cfg.max_seq_len
        self.B, self.S = B, S
        self.session = self.engine.new_session(batch=B, max_seq=S,
                                               prng_seed=prng_seed)
        self.slots: List[Optional[Request]] = [None] * B
        self.pending: List[Request] = []
        self._uid = itertools.count()

    # ----- request intake -----
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> Request:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        self.pending.append(req)
        return req

    # ----- admission: batch-1 prefill, insert into slot -----
    def _admit(self) -> List[Request]:
        """Fill free slots from the pending queue; retires requests whose
        prefill already finished them (max_new == 1 or first token == EOS)."""
        finished: List[Request] = []
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            first = self.session.prefill_row(
                slot, req.prompt, max_new_tokens=req.max_new_tokens,
                eos_token=req.eos_token)
            if req.max_new_tokens > 0:
                req.output.append(first)
            if self.session.row_done(slot):
                req.done = True
                finished.append(req)
            else:
                self.slots[slot] = req
        return finished

    # ----- one batched decode tick -----
    def step(self) -> List[Request]:
        """Admit, decode one strategy step for all live slots, retire
        finished. Returns the list of requests completed this tick."""
        finished = self._admit()
        if not np.any(self.session.live_rows()):
            return finished
        res = self.session.step()
        for slot in range(self.B):
            req = self.slots[slot]
            if req is None:
                continue
            req.output.extend(res.row_tokens(slot))
            req.exit_points.append(int(res.exit_layer[slot]))
            req.accept_lens.append(int(res.accept_len[slot]))
            if res.done[slot]:
                req.done = True
                finished.append(req)
                self.slots[slot] = None
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.pending and not np.any(self.session.live_rows()):
                break
        return done
