"""Continuous-batching serving engine — a slot loop over ``DecodeSession``
with managed cache memory and scheduled admission.

vLLM-style slot model adapted to JAX's static shapes:
  * ``max_batch`` slots share one batched ``DecodeSession`` whose memory is
    owned by a ``KVCacheManager`` (``repro.api.cache``): **paged KV** by
    default (``ServeConfig.page_size`` pages + per-row page tables, free-page
    admission control), with the slot-masked dense layout available as the
    bit-identical reference (``cache="dense"``);
  * admission runs through the ``ChunkedPrefillScheduler``
    (``repro.api.scheduler``): prompts split into ``ServeConfig.
    prefill_chunk``-token chunks interleaved with decode ticks
    (Sarathi-style) — a live batch is never stalled more than one chunk
    budget per tick. ``prefill_chunk=0`` restores blocking whole-prompt
    admission;
  * every engine tick runs ONE batched strategy step for all live slots —
    dense, AR-SpecEE, or tree speculative decoding behind the same
    ``StepResult`` surface; finished rows retire *and compact*
    (``session.retire_row``): their pages return to the pool and their
    logical length drops to zero, so long-idle slots stop paying attention
    span — exactly the iteration-level scheduling of Orca/vLLM;
  * inactive slots are masked; their compute is wasted but bounded (the
    standard TPU static-batch trade-off; see DESIGN.md §3), and after
    compaction an idle slot's attention span is ~zero rather than its stale
    context length.

Serve-path adoption (ROADMAP): the engine defaults the fused exit-gate
pipeline ON (``ModelFlags.exit_gate_kernel``) — pass ``fused_gate=False`` to
pin the reference path. Sampling modes come from ``run.serve`` (greedy /
temperature) on the dense strategy; ``prng_seed`` seeds the session's PRNG
stream so sampled runs are reproducible per seed.

This engine is the PC/cloud *logic* deliverable; the multi-pod path lowers
the same strategy step through pjit (launch/serve.py, launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.api import (CacheSpec, DecodeStrategy, DenseStrategy, Engine,
                       get_strategy)
from repro.api.scheduler import ChunkedPrefillScheduler
from repro.models.model import Model, build_model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    accept_lens: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, sw=None, specee: bool = True,
                 strategy: Union[str, DecodeStrategy, None] = None,
                 prng_seed: int = 0, fused_gate: bool = True,
                 cache: Union[None, str, CacheSpec] = "paged",
                 page_size: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        spec = CacheSpec.resolve(cache, model.run.serve)
        if page_size is not None:
            # the override obeys the same rule ServeConfig validates at
            # construction (pages tile the cache exactly)
            if page_size <= 0 or model.run.serve.max_seq_len % page_size:
                raise ValueError(
                    f"page_size ({page_size}) must be > 0 and divide "
                    f"max_seq_len ({model.run.serve.max_seq_len})")
            spec = dataclasses.replace(spec, page_size=page_size)
        self.cache_spec = spec
        flags = model.flags
        if bool(fused_gate) != getattr(flags, "exit_gate_kernel", False):
            flags = dataclasses.replace(flags,
                                        exit_gate_kernel=bool(fused_gate))
        # paged serving pairs with the page-table-aware decode kernel on real
        # hardware; off-TPU the kernel would run in interpret mode, so the
        # XLA gather path stays (same tokens — the kernel is a perf variant)
        if (spec.kind == "paged" and not flags.decode_kernel
                and jax.default_backend() == "tpu"):
            flags = dataclasses.replace(flags, decode_kernel=True)
        if flags is not model.flags:
            model = build_model(model.run, flags)
        self.model = model
        self.serve_cfg = model.run.serve
        if strategy is None:
            if specee and model.run.specee.enabled:
                strategy = "specee"
            elif self.serve_cfg.greedy:
                strategy = "dense"
            else:
                strategy = DenseStrategy(
                    temperature=self.serve_cfg.temperature)
        self.strategy = get_strategy(strategy)
        self.engine = Engine.create(model, params, sw=sw,
                                    strategy=self.strategy)
        B = self.serve_cfg.max_batch
        S = self.serve_cfg.max_seq_len
        self.B, self.S = B, S
        self.session = self.engine.new_session(batch=B, max_seq=S,
                                               prng_seed=prng_seed,
                                               cache=self.cache_spec)
        chunk = (self.serve_cfg.prefill_chunk if prefill_chunk is None
                 else prefill_chunk)
        self.scheduler = ChunkedPrefillScheduler(
            self.session, chunk_tokens=chunk or None)
        self.slots: List[Optional[Request]] = [None] * B
        self._inflight: Dict[int, Request] = {}
        self._uid = itertools.count()

    # ----- request intake -----
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> Request:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        self._inflight[req.uid] = req
        self.scheduler.submit(req.uid, req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              eos_token=req.eos_token)
        return req

    @property
    def pending(self) -> List[Request]:
        """Requests not yet slotted: queued + the in-flight chunked
        admission (back-compat view — pre-PR3 a request stayed in
        ``pending`` until it occupied a slot)."""
        return [self._inflight[uid] for uid in
                self.scheduler.admitting + self.scheduler.queued]

    def _retire(self, row: int, req: Request,
                finished: List[Request]) -> None:
        req.done = True
        finished.append(req)
        self.slots[row] = None
        self.session.retire_row(row)    # compaction: free pages, zero span

    # ----- one batched engine tick -----
    def step(self) -> List[Request]:
        """Scheduled admission (≤ one prefill chunk while decode is live),
        one strategy step for all live slots, retire + compact finished.
        Returns the list of requests completed this tick."""
        finished: List[Request] = []
        live = bool(np.any(self.session.live_rows()))
        free = [s for s in range(self.B) if self.slots[s] is None]
        for ev in self.scheduler.tick(free, live_decode=live):
            req = self._inflight.pop(ev.uid)
            if req.max_new_tokens > 0:
                req.output.append(ev.first_token)
            if self.session.row_done(ev.row):
                self._retire(ev.row, req, finished)
            else:
                self.slots[ev.row] = req
        if not np.any(self.session.live_rows()):
            return finished
        res = self.session.step()
        for slot in range(self.B):
            req = self.slots[slot]
            if req is None:
                continue
            req.output.extend(res.row_tokens(slot))
            req.exit_points.append(int(res.exit_layer[slot]))
            req.accept_lens.append(int(res.accept_len[slot]))
            if res.done[slot]:
                self._retire(slot, req, finished)
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if (not self.scheduler.has_work()
                    and not np.any(self.session.live_rows())):
                break
        return done
