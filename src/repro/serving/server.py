"""Continuous-batching serving engine — a slot loop over ``DecodeSession``
with managed cache memory, scheduled admission, and fault tolerance.

vLLM-style slot model adapted to JAX's static shapes:
  * ``max_batch`` slots share one batched ``DecodeSession`` whose memory is
    owned by a ``KVCacheManager`` (``repro.api.cache``): **paged KV** by
    default (``ServeConfig.page_size`` pages + per-row page tables, free-page
    admission control), with the slot-masked dense layout available as the
    bit-identical reference (``cache="dense"``);
  * admission runs through the ``ChunkedPrefillScheduler``
    (``repro.api.scheduler``): prompts split into ``ServeConfig.
    prefill_chunk``-token chunks interleaved with decode ticks
    (Sarathi-style) — a live batch is never stalled more than one chunk
    budget per tick. ``prefill_chunk=0`` restores blocking whole-prompt
    admission;
  * every engine tick runs ONE batched strategy step for all live slots —
    dense, AR-SpecEE, or tree speculative decoding behind the same
    ``StepResult`` surface; finished rows retire *and compact*
    (``session.retire_row``): their pages return to the pool and their
    logical length drops to zero, so long-idle slots stop paying attention
    span — exactly the iteration-level scheduling of Orca/vLLM;
  * inactive slots are masked; their compute is wasted but bounded (the
    standard TPU static-batch trade-off; see DESIGN.md §3), and after
    compaction an idle slot's attention span is ~zero rather than its stale
    context length.

Serve-path adoption (ROADMAP): the engine defaults the fused exit-gate
pipeline ON (``ModelFlags.exit_gate_kernel``) — pass ``fused_gate=False`` to
pin the reference path. Sampling modes come from ``run.serve`` (greedy /
temperature) on the dense strategy; ``prng_seed`` seeds the session's PRNG
stream so sampled runs are reproducible per seed.

Device-resident multi-tick decode (PR 5, DESIGN.md §6): ``megatick=K`` folds
K decode ticks into one fused ``lax.while_loop`` dispatch (budget/EOS/done
accounting in the jitted carry — host sync once per K tokens instead of once
per token), and ``async_ticks`` (default ON when K > 1) pipelines the loop:
``step()`` dispatches megatick N+1 BEFORE blocking on megatick N's results,
so host-side detokenization, retirement, and chunked admission overlap
device compute. The pipeline is correct because the done mask rides in the
device carry (a megatick dispatched against rows that just finished runs
zero device ticks), at the cost of results and admissions lagging one
``step()`` call — ``run_to_completion`` drains the in-flight handle.

Fault tolerance (this PR, DESIGN.md §7) composes four mechanisms:
  * **checkpoint/restore** — ``checkpoint_now()`` drains the in-flight
    megatick, snapshots the session (device state + host mirrors + page
    allocator) plus the engine's request/queue/slot bookkeeping through
    ``repro.checkpoint``, and a fresh engine's ``restore_checkpoint()``
    resumes token-identically. Wired to SIGTERM via ``PreemptionGuard``:
    the next ``step()`` after the signal checkpoints and raises
    ``Preempted``.
  * **pool-pressure eviction** — when the scheduler's queue head sits
    blocked on ``can_admit`` for ``evict_patience`` consecutive ticks with
    a slot free, ``VictimPolicy`` picks a live row to evict: its pages are
    freed and the request requeues with its ORIGINAL prompt. After
    readmission the row deterministically re-emits its recorded tokens,
    which the engine *verifies* against the recorded output instead of
    re-appending (the recompute-prefix invariant) — divergence surfaces as
    ``ServingFault(site="replay")``.
  * **watchdog + backoff** — megatick dispatch retries through ``Backoff``
    before surfacing ``ServingFault(site="dispatch")``; a wedged or
    poisoned finish (``finish_timeout`` / ``nan_logits`` fault-injection
    sites, out-of-vocab token validation) aborts the async pipeline,
    evicts the affected rows (replay regenerates the lost tokens), and
    falls back to the synchronous tick path for ``cooldown_ticks``.
  * **fault log** — every recovery action lands in ``fault_log`` so tests
    assert the intended degradation path actually ran.

This engine is the PC/cloud *logic* deliverable; the multi-pod path lowers
the same strategy step through pjit (launch/serve.py, launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.api import (CacheSpec, DecodeStrategy, DenseStrategy, Engine,
                       get_strategy)
from repro.api.scheduler import ChunkedPrefillScheduler
from repro.checkpoint import CheckpointManager
from repro.models.model import Model, build_model
from repro.runtime import faultinject
from repro.runtime.fault import PreemptionGuard, plan_replica_remesh
from repro.serving.resilience import (Backoff, FaultEvent, FaultLog,
                                      Preempted, ServingFault, VictimInfo,
                                      VictimPolicy)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 32
    eos_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    exit_points: List[int] = field(default_factory=list)
    accept_lens: List[int] = field(default_factory=list)
    done: bool = False
    # eviction/recompute bookkeeping: after an eviction the first
    # ``replay_total`` tokens the re-admitted row emits are VERIFIED against
    # ``output`` (already recorded) rather than appended; ``replayed`` is the
    # verification cursor and ``evictions`` feeds VictimPolicy's protection
    replay_total: int = 0
    replayed: int = 0
    evictions: int = 0

    @property
    def replaying(self) -> bool:
        return self.replayed < self.replay_total


class ServingEngine:
    def __init__(self, model: Model, params, sw=None, specee: bool = True,
                 strategy: Union[str, DecodeStrategy, None] = None,
                 prng_seed: int = 0, fused_gate: bool = True,
                 cache: Union[None, str, CacheSpec] = "paged",
                 page_size: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 megatick: int = 1,
                 async_ticks: Optional[bool] = None,
                 checkpoint_dir: Optional[str] = None,
                 guard: Optional[PreemptionGuard] = None,
                 victim: Optional[VictimPolicy] = None,
                 evict_patience: int = 2,
                 watchdog_s: Optional[float] = None,
                 backoff: Optional[Backoff] = None,
                 cooldown_ticks: int = 8,
                 quant=None,
                 mesh=None, policy: str = "tp_dp",
                 fault_log_cap: int = 256):
        spec = CacheSpec.resolve(cache, model.run.serve)
        if page_size is not None:
            # the override obeys the same rule ServeConfig validates at
            # construction (pages tile the cache exactly)
            if page_size <= 0 or model.run.serve.max_seq_len % page_size:
                raise ValueError(
                    f"page_size ({page_size}) must be > 0 and divide "
                    f"max_seq_len ({model.run.serve.max_seq_len})")
            spec = dataclasses.replace(spec, page_size=page_size)
        self.cache_spec = spec
        flags = model.flags
        if bool(fused_gate) != getattr(flags, "exit_gate_kernel", False):
            flags = dataclasses.replace(flags,
                                        exit_gate_kernel=bool(fused_gate))
        # paged serving pairs with the page-table-aware decode kernel on real
        # hardware; off-TPU the kernel would run in interpret mode, so the
        # XLA gather path stays (same tokens — the kernel is a perf variant)
        if (spec.kind == "paged" and not flags.decode_kernel
                and jax.default_backend() == "tpu"):
            flags = dataclasses.replace(flags, decode_kernel=True)
        if flags is not model.flags:
            model = build_model(model.run, flags)
        self.model = model
        self.serve_cfg = model.run.serve
        if strategy is None:
            if specee and model.run.specee.enabled:
                strategy = "specee"
            elif self.serve_cfg.greedy:
                strategy = "dense"
            else:
                strategy = DenseStrategy(
                    temperature=self.serve_cfg.temperature)
        self.strategy = get_strategy(strategy)
        # ``quant``: None | "int8" | "int4" | QuantSpec — weight-only
        # compression applied once at engine build (parallel pytree; the
        # fp params are untouched and stay the checkpoint of record)
        # ``mesh``: a 2-D ("data","model") jax Mesh turns on tensor-parallel
        # decode for THIS engine (DESIGN.md §9); data parallelism lives one
        # level up in ``repro.serving.replica.ReplicaPool``
        self.engine = Engine.create(model, params, sw=sw,
                                    strategy=self.strategy, quant=quant,
                                    mesh=mesh, policy=policy)
        # remesh sources (DESIGN.md §10): ``Engine.create`` pins sharded
        # copies under the mesh's specs but never mutates the host pytrees,
        # so these references are all a device-loss rebuild needs — no
        # checkpoint round-trip
        self._src_params, self._src_sw = params, sw
        self._src_quant, self._src_policy = quant, policy
        self._src_seed = prng_seed
        B = self.serve_cfg.max_batch
        S = self.serve_cfg.max_seq_len
        self.B, self.S = B, S
        self.session = self.engine.new_session(batch=B, max_seq=S,
                                               prng_seed=prng_seed,
                                               cache=self.cache_spec)
        chunk = (self.serve_cfg.prefill_chunk if prefill_chunk is None
                 else prefill_chunk)
        self.scheduler = ChunkedPrefillScheduler(
            self.session, chunk_tokens=chunk or None)
        self.slots: List[Optional[Request]] = [None] * B
        self._inflight: Dict[int, Request] = {}
        self._next_uid = 0
        if megatick < 1:
            raise ValueError(f"megatick must be >= 1, got {megatick}")
        self.megatick = int(megatick)
        # pipelined ticks default ON whenever megaticks are on: the whole
        # point of folding K ticks into one dispatch is to overlap the
        # host work with device compute
        self.async_ticks = (self.megatick > 1 if async_ticks is None
                            else bool(async_ticks))
        self._handle: Optional[Tuple] = None   # in-flight async megatick
        # ----- fault tolerance (DESIGN.md §7) -----
        self.checkpoint_dir = checkpoint_dir
        # sync saves: a preemption checkpoint must be durable before the
        # process exits, and serving snapshots are small
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=2,
                                       async_save=False)
                     if checkpoint_dir else None)
        self._own_guard = guard is None and checkpoint_dir is not None
        self.guard = (guard if guard is not None
                      else (PreemptionGuard() if checkpoint_dir else None))
        if self._own_guard and self.guard is not None:
            self.guard.install()
        self.victim = victim if victim is not None else VictimPolicy()
        self.evict_patience = int(evict_patience)
        self.watchdog_s = watchdog_s
        self.backoff = backoff if backoff is not None else Backoff()
        self.cooldown_ticks = int(cooldown_ticks)
        self._sync_cooldown = 0         # ticks left on the sync fallback path
        self._tick = 0
        self.fault_log = FaultLog(cap=fault_log_cap)
        self.completed: List[Request] = []   # finish order, survives restore

    @property
    def tp_degree(self) -> int:
        """Current tensor-parallel degree (1 = unsharded; drops on remesh)."""
        shard = self.engine.shard
        return shard.degree if shard is not None else 1

    # ----- request intake -----
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> Request:
        req = Request(uid=self._next_uid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        self._next_uid += 1
        self._inflight[req.uid] = req
        self.scheduler.submit(req.uid, req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              eos_token=req.eos_token)
        return req

    def adopt(self, prompt: np.ndarray, max_new_tokens: int = 32,
              eos_token: Optional[int] = None, recorded=(),
              stats=None) -> Request:
        """Admit a request that already emitted ``recorded`` tokens on
        ANOTHER engine (replica failover, DESIGN.md §9). The request
        re-prefills here and its first ``len(recorded)`` tokens run as
        verified replay — the PR-6 recompute invariant, which holds across
        replicas because they share weights and decode is deterministic —
        before new tokens append. ``stats`` optionally seeds the
        (exit_points, accept_lens) recorded so far, so the finished request's
        stats match an uninterrupted run. Empty ``recorded`` behaves exactly
        like ``submit``."""
        req = Request(uid=self._next_uid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token,
                      output=[int(t) for t in recorded],
                      replay_total=len(recorded), replayed=0)
        if stats is not None:
            req.exit_points = [int(x) for x in stats[0]]
            req.accept_lens = [int(x) for x in stats[1]]
        self._next_uid += 1
        self._inflight[req.uid] = req
        self.scheduler.submit(req.uid, req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              eos_token=req.eos_token)
        return req

    @property
    def pending(self) -> List[Request]:
        """Requests not yet slotted: queued + the in-flight chunked
        admission (back-compat view — pre-PR3 a request stayed in
        ``pending`` until it occupied a slot)."""
        return [self._inflight[uid] for uid in
                self.scheduler.admitting + self.scheduler.queued]

    def _retire(self, row: int, req: Request,
                finished: List[Request]) -> None:
        req.done = True
        finished.append(req)
        self.slots[row] = None
        self.session.retire_row(row)    # compaction: free pages, zero span

    # ----- token accounting (replay-aware) -----
    def _admit_token(self, req: Request, tok: int) -> None:
        """Record a request's first token (admission). A re-admitted evicted
        request is in replay: the token is verified, not re-appended."""
        if req.replaying:
            want = int(req.output[req.replayed])
            if int(tok) != want:
                raise ServingFault(
                    "replay", f"uid={req.uid} diverged at token "
                    f"{req.replayed}: re-admission produced {int(tok)}, "
                    f"recorded {want}")
            req.replayed += 1
        else:
            req.output.append(int(tok))

    def _fold_tick(self, req: Request, toks: List[int], exit_point: int,
                   accept_len: int) -> None:
        """Fold one live device tick of one row into the request.

        Replay ticks (tokens the request emitted before an eviction) verify
        against the recorded output and contribute NO stats — their stats
        were recorded the first time, so the final exit_points/accept_lens
        match an uninterrupted run exactly. A tick that straddles the replay
        boundary verifies its prefix and appends the remainder (cannot
        happen when eviction sits on a tick boundary, which it always does —
        the engine drains the in-flight megatick before evicting — but the
        fold is tolerant)."""
        i = 0
        if req.replaying:
            n = min(len(toks), req.replay_total - req.replayed)
            want = [int(t) for t in req.output[req.replayed:req.replayed + n]]
            got = [int(t) for t in toks[:n]]
            if got != want:
                raise ServingFault(
                    "replay", f"uid={req.uid} diverged at token "
                    f"{req.replayed}: replay produced {got}, recorded {want}")
            req.replayed += n
            i = n
            if req.replaying or i == len(toks):
                return                  # fully-replayed tick: stats recorded
        req.output.extend(int(t) for t in toks[i:])
        req.exit_points.append(int(exit_point))
        req.accept_lens.append(int(accept_len))

    def _collect(self, res, slots: List[Optional[Request]],
                 finished: List[Request]) -> None:
        """Fold one (possibly multi-tick) StepResult into the requests that
        occupied the slots WHEN THE MEGATICK WAS DISPATCHED: detokenize,
        per-tick exit stats, retire + compact. The snapshot matters in the
        async pipeline — a slot can be re-admitted between a megatick's
        dispatch and its finish, and the old result must not be attributed
        to (or retire) the new occupant. A request already retired by an
        earlier finish is skipped (later megaticks report it done again but
        emit nothing for it — the device done-mask guarantees counts 0)."""
        for slot in range(self.B):
            req = slots[slot]
            if req is None or req.done:
                continue
            toks = res.row_tokens(slot)
            if res.is_megatick:
                # (B, K·W) tokens are packed left-aligned in tick order, so
                # tick_counts slices them back into per-tick runs
                off = 0
                for t in range(int(res.ticks)):
                    if not bool(res.tick_live[slot, t]):
                        continue
                    n = int(res.tick_counts[slot, t])
                    self._fold_tick(req, toks[off:off + n],
                                    int(res.exit_layer[slot, t]),
                                    int(res.accept_len[slot, t]))
                    off += n
            else:
                self._fold_tick(req, toks, int(res.exit_layer[slot]),
                                int(res.accept_len[slot]))
            if res.done[slot]:
                # req not done => its slot has not been re-admitted (slots
                # only free at retirement), so slots[slot] is still req
                self._retire(slot, req, finished)

    # ----- dispatch / finish with recovery -----
    def _attempt(self, site: str, fn):
        """Run ``fn`` with the engine's backoff schedule; exhausting the
        retries surfaces a structured ``ServingFault`` carrying the site,
        the attempt count, and the last underlying error."""
        delays = list(self.backoff.delays())
        last: Optional[BaseException] = None
        for i in range(len(delays) + 1):
            try:
                return fn()
            except (ServingFault, KeyboardInterrupt):
                raise
            except Exception as err:
                last = err
                retrying = i < len(delays)
                self.fault_log.append(FaultEvent(
                    site=site, tick=self._tick,
                    action="retry" if retrying else "give_up",
                    detail=repr(err)))
                if retrying:
                    self.backoff.sleep(delays[i])
        raise ServingFault(site,
                           f"failed after {len(delays) + 1} attempts: "
                           f"{last!r}",
                           attempts=len(delays) + 1, cause=last) from last

    def _dispatch(self) -> Optional[Tuple]:
        """Dispatch one megatick (plus the slot snapshot its results will be
        attributed to) if any row may still be live. The host view can trail
        the device by one in-flight megatick, but only toward liveness (rows
        never un-finish between admissions), so a stale dispatch at worst
        runs zero device ticks. Dispatch failures retry through the backoff
        schedule — the fault-injection ``dispatch`` site (and any real error
        raised before the jit call donates the state) leaves the session
        intact, so a retry is safe."""
        if np.any(self.session.live_rows()):
            handle = self._attempt(
                "dispatch", lambda: self.session.step_async(self.megatick))
            return handle, list(self.slots)
        return None

    def _checked(self, res) -> Tuple[object, bool]:
        """Validate a step result's tokens against the vocab range (the
        cheap host-side canary for device corruption — a NaN'd logits bank
        argmaxes/samples into garbage ids). The ``nan_logits`` injection
        site poisons the result here to exercise the recovery path."""
        tokens = np.asarray(res.tokens)
        if faultinject.fire("nan_logits"):
            tokens = np.full_like(tokens, -(1 << 30))
            res = res._replace(tokens=tokens)
        V = self.model.run.model.vocab_size
        counts = np.asarray(res.counts)
        for row in range(tokens.shape[0]):
            n = int(counts[row])
            if n and (np.any(tokens[row, :n] < 0)
                      or np.any(tokens[row, :n] >= V)):
                return res, False
        return res, True

    def _recover_lost(self, site: str, detail: str) -> None:
        """A megatick's results are lost or untrustworthy (wedged finish,
        poisoned tokens): abort the async pipeline and evict every live
        slotted request. The evictions requeue them with their original
        prompts; deterministic replay regenerates the lost tokens, so the
        recovery costs recompute but never output. Then cool down on the
        synchronous tick path."""
        self.session.abort_async()
        self._handle = None
        evicted = 0
        for row in range(self.B):
            req = self.slots[row]
            if req is not None and not req.done:
                self._evict(row, req, reason=site)
                evicted += 1
        self._sync_cooldown = self.cooldown_ticks
        self.fault_log.append(FaultEvent(
            site=site, tick=self._tick, action="recover",
            detail=f"{detail}; evicted={evicted} rows, sync cooldown "
                   f"{self.cooldown_ticks} ticks"))

    def _finish_handle(self, prev: Tuple, finished: List[Request]) -> None:
        """Block on a dispatched megatick and fold its results in, guarding
        the three failure modes: an injected wedge (``finish_timeout`` —
        results never arrive), poisoned tokens (``nan_logits`` / vocab-range
        validation), and a *slow but successful* finish (wall-clock over
        ``watchdog_s`` — results are kept, but the engine falls back to the
        sync path for ``cooldown_ticks`` so a degraded device stops
        accumulating in-flight work)."""
        handle, slots_at_dispatch = prev
        if faultinject.fire("finish_timeout"):
            self._recover_lost("finish_timeout",
                               "megatick finish wedged past watchdog")
            return
        t0 = time.monotonic()
        res = self.session.finish_step(handle)
        dt = time.monotonic() - t0
        res, ok = self._checked(res)
        if not ok:
            self._recover_lost("nan_logits",
                               "out-of-vocab tokens in megatick result")
            return
        if self.watchdog_s is not None and dt > self.watchdog_s:
            self._sync_cooldown = self.cooldown_ticks
            self.fault_log.append(FaultEvent(
                site="watchdog", tick=self._tick, action="sync_fallback",
                detail=f"finish blocked {dt * 1e3:.1f}ms > "
                       f"{self.watchdog_s * 1e3:.1f}ms"))
        self._collect(res, slots_at_dispatch, finished)

    def _drain(self, finished: List[Request]) -> None:
        """Finish the in-flight async megatick, if any, without dispatching
        a replacement (checkpoint / eviction barrier)."""
        prev, self._handle = self._handle, None
        if prev is not None:
            self._finish_handle(prev, finished)

    def _sync_step(self, finished: List[Request]) -> None:
        res = self._attempt(
            "dispatch", lambda: self.session.step(num_ticks=self.megatick))
        res, ok = self._checked(res)
        if not ok:
            self._recover_lost("nan_logits",
                               "out-of-vocab tokens in step result")
            return
        self._collect(res, self.slots, finished)

    # ----- pool-pressure eviction -----
    def _evict(self, row: int, req: Request, reason: str) -> None:
        """Evict a live row: free its pages, requeue the request with its
        ORIGINAL prompt. Deterministic replay re-emits (and the engine
        verifies) the already-recorded tokens after re-admission."""
        req.evictions += 1
        req.replay_total = len(req.output)
        req.replayed = 0
        self.slots[row] = None
        self.session.retire_row(row)    # pages back to the pool
        self._inflight[req.uid] = req
        self.scheduler.submit(req.uid, req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              eos_token=req.eos_token)
        self.fault_log.append(FaultEvent(
            site=reason, tick=self._tick, action="evict",
            detail=f"uid={req.uid} row={row} progress={len(req.output)} "
                   f"evictions={req.evictions}"))

    def _maybe_evict(self, finished: List[Request]) -> None:
        """Pool-pressure graceful degradation: the queue head has been
        blocked on ``can_admit`` for ``evict_patience`` consecutive ticks
        while a slot sat free — evict the policy's victim so admission can
        proceed. The in-flight megatick drains FIRST so its tokens land in
        the victim's record before ``replay_total`` freezes (otherwise the
        late finish would append tokens the replay then duplicates)."""
        if self.scheduler.deferred_ticks < self.evict_patience:
            return
        self._drain(finished)
        cands = []
        for row in range(self.B):
            req = self.slots[row]
            if req is None or req.done:
                continue
            cands.append(VictimInfo(row=row, progress=len(req.output),
                                    pages=self.session.row_span(row),
                                    evictions=req.evictions))
        row = self.victim.select(cands)
        if row is None:
            return                      # every candidate is protected
        self._evict(row, self.slots[row], reason="pool_pressure")
        self.scheduler.deferred_ticks = 0

    # ----- elastic remesh on device loss (DESIGN.md §10) -----
    def remesh(self, mesh, site: str = "device_lost",
               detail: str = "") -> None:
        """Rebuild the decode stack on ``mesh`` (None = unsharded) and
        re-admit every unfinished request with verified replay.

        Order matters: the in-flight megatick drains FIRST so its tokens
        land in each request's record before ``replay_total`` freezes (the
        eviction invariant); then the chunked admission aborts back to the
        queue; then a fresh ``Engine`` re-``device_put``s the HOST
        params/spec-weights under the new mesh's Megatron specs, a fresh
        session re-shards the paged pools (``shard_state`` at alloc) and
        re-traces step/megatick for the new ``ShardCtx``. Re-admitted
        requests replay-verify their recorded tokens (PR 6/9: decode is
        deterministic and sharded ≡ unsharded, so the degraded engine is
        token-identical to the healthy run); stats recorded pre-remesh stay
        on the request, and replay ticks contribute none — the finished
        stats match an uninterrupted run exactly."""
        finished: List[Request] = []
        self._drain(finished)
        self.completed.extend(finished)
        self.scheduler.abort_active()
        chunk = self.scheduler.chunk_tokens
        pending: List[Request] = [
            req for req in self.slots if req is not None and not req.done]
        pending.extend(self._inflight[uid] for uid in self.scheduler.queued)
        # admission order on the rebuilt engine is uid order — deterministic
        # regardless of which rows happened to be slotted at the loss
        pending.sort(key=lambda r: r.uid)
        old_tp = self.tp_degree
        self.engine = Engine.create(self.model, self._src_params,
                                    sw=self._src_sw, strategy=self.strategy,
                                    quant=self._src_quant, mesh=mesh,
                                    policy=self._src_policy)
        self.session = self.engine.new_session(batch=self.B, max_seq=self.S,
                                               prng_seed=self._src_seed,
                                               cache=self.cache_spec)
        self.scheduler = ChunkedPrefillScheduler(self.session,
                                                 chunk_tokens=chunk)
        self.slots = [None] * self.B
        self._inflight = {}
        self._handle = None
        for req in pending:
            req.replay_total = len(req.output)
            req.replayed = 0
            self._inflight[req.uid] = req
            self.scheduler.submit(req.uid, req.prompt,
                                  max_new_tokens=req.max_new_tokens,
                                  eos_token=req.eos_token)
        self.fault_log.append(FaultEvent(
            site=site, tick=self._tick, action="remesh",
            detail=f"tp {old_tp}->{self.tp_degree} "
                   f"readmitted={len(pending)}"
                   + (f"; {detail}" if detail else "")))

    def _maybe_device_loss(self) -> None:
        """The ``device_lost`` injection site: deterministically drop the
        HIGHEST device from this engine's mesh between ticks. With a valid
        factorization over the survivors (``plan_replica_remesh``) the
        engine rebuilds in place at the lower TP degree; with none (already
        unsharded, or no device left) it drains what it can and surfaces
        ``ServingFault(site="device_lost")`` — standalone that's terminal,
        under a ``ReplicaPool`` it's the kill-and-requeue fallback."""
        if not faultinject.fire("device_lost"):
            return
        mesh = self.engine.mesh
        devices = (list(mesh.devices.flat)
                   if mesh is not None and self.engine.shard is not None
                   else [])
        lost = devices[-1] if devices else None
        surviving = devices[:-1]
        new_tp = plan_replica_remesh(len(surviving), self.tp_degree)
        if new_tp is None:
            self.drain()
            self.fault_log.append(FaultEvent(
                site="device_lost", tick=self._tick, action="give_up",
                detail=f"no factorization over {len(surviving)} surviving "
                       f"devices (tp={self.tp_degree})"))
            raise ServingFault(
                "device_lost",
                f"device lost with no valid remesh (tp={self.tp_degree}, "
                f"surviving={len(surviving)})")
        if new_tp > 1:
            from repro.sharding.compat import make_mesh
            new_mesh = make_mesh((1, new_tp), ("data", "model"),
                                 devices=surviving[:new_tp])
        else:
            new_mesh = None
        self.remesh(new_mesh, detail=f"lost={lost}")

    def cancel(self, uid: int) -> bool:
        """Withdraw an unfinished request (deadline shedding): drop it from
        the queue/admission, or free its slot and pages. The in-flight
        megatick drains first so a slotted cancel retires a coherent row —
        if that drain FINISHES the request, it stays finished (it made the
        deadline after all). Returns True when the uid was found live."""
        if uid in self._inflight:
            if uid in self.scheduler.admitting:
                self.scheduler.abort_active()
            self.scheduler.remove(uid)
            del self._inflight[uid]
            return True
        for row in range(self.B):
            req = self.slots[row]
            if req is not None and req.uid == uid and not req.done:
                self.drain()
                if self.slots[row] is req and not req.done:
                    self.slots[row] = None
                    self.session.retire_row(row)
                return True
        return False

    # ----- checkpoint / restore (SIGTERM preemption) -----
    def _req_meta(self, req: Request) -> dict:
        return {"uid": int(req.uid),
                "prompt": [int(t) for t in req.prompt],
                "max_new": int(req.max_new_tokens),
                "eos": (None if req.eos_token is None
                        else int(req.eos_token)),
                "output": [int(t) for t in req.output],
                "exit_points": [int(x) for x in req.exit_points],
                "accept_lens": [int(x) for x in req.accept_lens],
                "done": bool(req.done),
                "replay_total": int(req.replay_total),
                "replayed": int(req.replayed),
                "evictions": int(req.evictions)}

    def _all_requests(self) -> Dict[int, Request]:
        reqs: Dict[int, Request] = {r.uid: r for r in self.completed}
        for r in self.slots:
            if r is not None:
                reqs[r.uid] = r
        reqs.update(self._inflight)
        return reqs

    def checkpoint_now(self) -> int:
        """Drain the in-flight megatick, snapshot the session + engine
        bookkeeping, write a step-atomic checkpoint. Returns the tick the
        checkpoint captures. The in-flight chunked admission is aborted back
        to the queue front (no pages are held until its final chunk, so the
        restore run simply re-prefills it)."""
        assert self.ckpt is not None, \
            "checkpoint_now() needs checkpoint_dir"
        self.drain()
        self.scheduler.abort_active()
        state, session_meta = self.session.snapshot()
        meta = {
            "session": session_meta,
            "serve": {
                "tick": int(self._tick),
                "uid_next": int(self._next_uid),
                "requests": [self._req_meta(r)
                             for r in self._all_requests().values()],
                "completed": [int(r.uid) for r in self.completed],
                "slots": [None if r is None else int(r.uid)
                          for r in self.slots],
                "queue": [int(u) for u in self.scheduler.queued],
            },
        }
        self.ckpt.save(self._tick, {"state": state}, extra=meta)
        self.fault_log.append(FaultEvent(
            site="sigterm", tick=self._tick, action="checkpoint",
            detail=f"saved tick {self._tick} to {self.ckpt.root}"))
        return self._tick

    def restore_checkpoint(self) -> bool:
        """Adopt the latest checkpoint into this freshly-built engine (same
        config). Returns False if the directory holds no committed
        checkpoint (first boot) — the engine then starts clean. After a
        True return the next ``step()`` continues the saved run
        token-identically."""
        assert self.ckpt is not None, \
            "restore_checkpoint() needs checkpoint_dir"
        hit = self.ckpt.restore_latest(like={"state": self.session._state})
        if hit is None:
            return False
        step, tree, extra = hit
        self.session.restore(tree["state"], extra["session"])
        sv = extra["serve"]
        self._tick = int(sv["tick"])
        self._next_uid = int(sv["uid_next"])
        reqs: Dict[int, Request] = {}
        for rm in sv["requests"]:
            reqs[int(rm["uid"])] = Request(
                uid=int(rm["uid"]),
                prompt=np.asarray(rm["prompt"], np.int32),
                max_new_tokens=int(rm["max_new"]),
                eos_token=(None if rm["eos"] is None else int(rm["eos"])),
                output=[int(t) for t in rm["output"]],
                exit_points=[int(x) for x in rm["exit_points"]],
                accept_lens=[int(x) for x in rm["accept_lens"]],
                done=bool(rm["done"]),
                replay_total=int(rm["replay_total"]),
                replayed=int(rm["replayed"]),
                evictions=int(rm["evictions"]))
        self.completed = [reqs[int(u)] for u in sv["completed"]]
        self.slots = [None if u is None else reqs[int(u)]
                      for u in sv["slots"]]
        self._inflight = {int(u): reqs[int(u)] for u in sv["queue"]}
        for uid in sv["queue"]:
            req = reqs[int(uid)]
            self.scheduler.submit(req.uid, req.prompt,
                                  max_new_tokens=req.max_new_tokens,
                                  eos_token=req.eos_token)
        self._handle = None
        self.fault_log.append(FaultEvent(
            site="sigterm", tick=self._tick, action="restore",
            detail=f"resumed from tick {step} in {self.ckpt.root}"))
        return True

    def _maybe_preempt(self) -> None:
        """SIGTERM (real, via ``PreemptionGuard``, or the ``sigterm``
        injection site) between ticks: drain, checkpoint if configured, and
        surface ``Preempted`` — the clean-shutdown signal for the launcher
        to exit and be restarted with ``--restore``."""
        hit = faultinject.fire("sigterm")
        if self.guard is not None and self.guard.should_save():
            hit = True
        if not hit:
            return
        if self.ckpt is not None:
            step = self.checkpoint_now()
            raise Preempted(step=step, path=self.ckpt.root)
        self.drain()
        raise Preempted(step=self._tick, path="")

    def close(self) -> None:
        """Release process-global hooks (the SIGTERM handler, if this engine
        installed its own guard)."""
        if self._own_guard and self.guard is not None:
            self.guard.uninstall()

    # ----- one batched engine tick -----
    def step(self) -> List[Request]:
        """Scheduled admission (≤ one prefill chunk while decode is live),
        one strategy megatick for all live slots, retire + compact finished.
        Returns the list of requests completed this call.

        With ``async_ticks`` the call is one pipeline stage: megatick N+1 is
        dispatched BEFORE megatick N's results are read, so the host work
        below (detokenization, retirement, chunked admission) overlaps device
        compute; results consequently arrive one call later than they did on
        the blocking path. During a recovery cooldown the pipeline is
        suspended and ticks run synchronously."""
        self._maybe_preempt()
        self._maybe_device_loss()
        self._tick += 1
        finished: List[Request] = []
        async_enabled = self.async_ticks and self._sync_cooldown == 0
        if self._sync_cooldown > 0:
            self._sync_cooldown -= 1
        prev, self._handle = self._handle, None
        if prev is not None:
            if async_enabled:
                # overlap: next megatick goes out before we block on this one
                self._handle = self._dispatch()
            self._finish_handle(prev, finished)
        live = bool(np.any(self.session.live_rows()))
        free = [s for s in range(self.B) if self.slots[s] is None]
        for ev in self.scheduler.tick(free, live_decode=live):
            req = self._inflight.pop(ev.uid)
            if req.max_new_tokens > 0:
                self._admit_token(req, ev.first_token)
            if self.session.row_done(ev.row):
                self._retire(ev.row, req, finished)
            else:
                self.slots[ev.row] = req
        self._maybe_evict(finished)
        if self._handle is None and np.any(self.session.live_rows()):
            if async_enabled:
                self._handle = self._dispatch()
            else:
                self._sync_step(finished)
        self.completed.extend(finished)
        return finished

    @property
    def in_flight(self) -> bool:
        """An async megatick is dispatched but its results are unread."""
        return self._handle is not None

    @property
    def busy(self) -> bool:
        """Work outstanding: queued/in-flight admission, live decode rows,
        or an in-flight async megatick awaiting its results."""
        return (self._handle is not None or self.scheduler.has_work()
                or bool(np.any(self.session.live_rows())))

    def drain(self) -> List[Request]:
        """Finish (without replacing) the in-flight async megatick; any
        requests it completes land in ``completed`` as usual."""
        finished: List[Request] = []
        self._drain(finished)
        self.completed.extend(finished)
        return finished

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.busy:
                return done
        raise ServingFault(
            "stall",
            f"still busy after {max_ticks} ticks: "
            f"queued={len(self.scheduler.queued)} "
            f"admitting={len(self.scheduler.admitting)} "
            f"live={int(np.sum(self.session.live_rows()))} "
            f"in_flight={self.in_flight}")
