"""Token samplers (greedy / temperature / top-k) for the serving engine.

SpecEE's verification is defined on greedy argmax (the paper evaluates greedy
and few-shot scoring); sampling modes apply to the dense path and to the
final-layer logits of non-exited rows.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(logits: jnp.ndarray, prng: jnp.ndarray, temperature: float = 0.0,
           top_k: Optional[int] = None) -> jnp.ndarray:
    """logits: (B, V) fp32 -> (B,) int32 tokens."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(prng, logits, axis=-1).astype(jnp.int32)
