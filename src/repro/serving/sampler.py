"""Token samplers (greedy / temperature / top-k) for the serving engine.

SpecEE's verification is defined on greedy argmax (the paper evaluates greedy
and few-shot scoring); sampling modes apply to the dense path and to the
final-layer logits of non-exited rows.

Sampled decode is keyed PER ROW from the session key, the row's absolute
position, and its previous token (``row_keys``) rather than from a
split-per-step stream. The key is therefore a pure function of the row's own
decode history — independent of batch composition, slot index, and global
step count — which is what makes fault recovery exact: an evicted row that
replays its prefix through the recompute path re-derives the same keys at
the same positions and resamples the identical tokens (the recompute-prefix
invariant, DESIGN.md §7). It is also what keeps ``step(num_ticks=K)``
trivially token-identical to K single steps: no PRNG carry threads between
ticks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def row_keys(prng: jnp.ndarray, pos: jnp.ndarray,
             last_token: jnp.ndarray) -> jnp.ndarray:
    """(B,) per-row sample keys = fold(fold(session_key, pos), last_token).

    ``pos``/``last_token``: (B,) int32 — the row's cache length BEFORE the
    step and the token being fed, i.e. row-local history only.
    """
    def one(p, t):
        return jax.random.fold_in(jax.random.fold_in(prng, p), t)
    return jax.vmap(one)(pos.astype(jnp.uint32),
                         last_token.astype(jnp.uint32))


def sample(logits: jnp.ndarray, prng: jnp.ndarray, temperature: float = 0.0,
           top_k: Optional[int] = None) -> jnp.ndarray:
    """logits: (B, V) fp32, one shared key -> (B,) int32 tokens."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _scale(logits, temperature, top_k)
    return jax.random.categorical(prng, logits, axis=-1).astype(jnp.int32)


def sample_rows(logits: jnp.ndarray, keys: jnp.ndarray,
                temperature: float = 0.0,
                top_k: Optional[int] = None) -> jnp.ndarray:
    """logits: (B, V) fp32, per-row keys (from ``row_keys``) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = _scale(logits, temperature, top_k)
    return jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg))(keys, logits) \
        .astype(jnp.int32)


def _scale(logits: jnp.ndarray, temperature: float,
           top_k: Optional[int]) -> jnp.ndarray:
    logits = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return logits
