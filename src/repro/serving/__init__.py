from repro.serving.server import Request, ServingEngine
