from repro.serving.replica import PoolRequest, ReplicaPool
from repro.serving.resilience import (Backoff, FaultEvent, FaultLog,
                                      LoadShedPolicy, PoolHealth, Preempted,
                                      ServingFault, VictimInfo, VictimPolicy)
from repro.serving.server import Request, ServingEngine

__all__ = ["Backoff", "FaultEvent", "FaultLog", "LoadShedPolicy",
           "PoolHealth", "PoolRequest", "Preempted", "ReplicaPool",
           "Request", "ServingEngine", "ServingFault", "VictimInfo",
           "VictimPolicy"]
