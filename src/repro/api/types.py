"""Canonical result type of the unified decode API.

Every decode strategy — dense full-depth, AR SpecEE, tree speculative —
emits the SAME shape of result per step. This is the API-level expression of
the paper's merged-mapping insight ("different decoding methods share the
same essential characteristics"): a 1-token AR emit is just a tree emit with
``counts == 1``, so the serving engine, the launchers, and every example can
drive all three modes through one loop.
"""
from __future__ import annotations

from typing import Any, NamedTuple


class StepResult(NamedTuple):
    """One decode tick for every row of the session batch.

    The token buffer is FIXED-WIDTH (``W = strategy.emit_width``, e.g. 1 for
    dense/AR, tree depth + 1 for tree mode) with a per-row valid count —
    static shapes under jit, ragged semantics on top.
    """
    tokens: Any        # (B, W) int32 — left-aligned emitted tokens
    counts: Any        # (B,)   int32 — valid tokens this tick (0 for a done
    #                     row once the session truncates it)
    done: Any          # (B,)   bool  — row finished (eos / budget); always
    #                     False from a raw strategy step, filled in by the
    #                     session's host-side bookkeeping
    exit_layer: Any    # (B,)   int32 — exit point taken (E if full depth)
    accept_len: Any    # (B,)   int32 — accepted draft tokens (tree mode;
    #                     0 for dense/AR)
    exited: Any        # (B,)   bool  — predictor-driven early exit happened
    units_run: Any     # ()     int32 — units the layer loop executed

    @property
    def batch(self) -> int:
        return self.tokens.shape[0]

    @property
    def width(self) -> int:
        return self.tokens.shape[1]

    def row_tokens(self, row: int):
        """Host-side convenience: the valid tokens of one row as a list."""
        n = int(self.counts[row])
        return [int(t) for t in self.tokens[row, :n]]
