"""Canonical result type of the unified decode API.

Every decode strategy — dense full-depth, AR SpecEE, tree speculative —
emits the SAME shape of result per step. This is the API-level expression of
the paper's merged-mapping insight ("different decoding methods share the
same essential characteristics"): a 1-token AR emit is just a tree emit with
``counts == 1``, so the serving engine, the launchers, and every example can
drive all three modes through one loop.
"""
from __future__ import annotations

from typing import Any, NamedTuple


class StepResult(NamedTuple):
    """One decode tick for every row of the session batch.

    The token buffer is FIXED-WIDTH (``W = strategy.emit_width``, e.g. 1 for
    dense/AR, tree depth + 1 for tree mode) with a per-row valid count —
    static shapes under jit, ragged semantics on top.

    A MEGATICK result (``DecodeSession.step(num_ticks=K)`` with K > 1, or any
    ``step_async``) widens the same contract to K device ticks: ``tokens`` is
    (B, K·W) with ``counts`` the per-row total across the megatick, and the
    per-tick stat fields become (B, K) planes with ``tick_live`` marking
    which ticks each row was live for (``ticks`` is how many device ticks
    actually ran — the loop early-exits once every row is done). Single-tick
    results keep the historical (B,) stat shapes with the trailing fields at
    their defaults, so existing consumers are untouched; tick-aware consumers
    use ``row_exit_points``/``row_accept_lens``, which handle both shapes.
    """
    tokens: Any        # (B, W) int32 — left-aligned emitted tokens
    #                     (megatick: (B, K*W), still left-aligned per row)
    counts: Any        # (B,)   int32 — valid tokens this tick (0 for a done
    #                     row once the session truncates it)
    done: Any          # (B,)   bool  — row finished (eos / budget); always
    #                     False from a raw strategy step, filled in by the
    #                     session's bookkeeping (host-side for single steps,
    #                     the device-resident carry for megaticks)
    exit_layer: Any    # (B,)   int32 — exit point taken (E if full depth)
    #                     (megatick: (B, K) per-tick plane)
    accept_len: Any    # (B,)   int32 — accepted draft tokens (tree mode;
    #                     0 for dense/AR) (megatick: (B, K))
    exited: Any        # (B,)   bool  — predictor-driven early exit happened
    #                     (megatick: (B, K))
    units_run: Any     # ()     int32 — units the layer loop executed
    #                     (megatick: summed over the ticks that ran)
    ticks: Any = 1     # ()     int   — device ticks folded into this result
    tick_counts: Any = None   # (B, K) int32 — kept tokens per tick
    #                     (megatick only; None for single-tick results)
    tick_live: Any = None     # (B, K) bool — row live entering each tick
    #                     (megatick only; None for single-tick results)

    @property
    def batch(self) -> int:
        return self.tokens.shape[0]

    @property
    def width(self) -> int:
        return self.tokens.shape[1]

    @property
    def is_megatick(self) -> bool:
        """Whether the per-tick stat fields are (B, K) planes."""
        return self.tick_live is not None

    def row_tokens(self, row: int):
        """Host-side convenience: the valid tokens of one row as a list."""
        n = int(self.counts[row])
        return [int(t) for t in self.tokens[row, :n]]

    def row_exit_points(self, row: int):
        """Exit layer per live tick of one row (a 1-element list for a
        single-tick result — the historical per-step consumer contract)."""
        if not self.is_megatick:
            return [int(self.exit_layer[row])]
        return [int(self.exit_layer[row, t]) for t in range(int(self.ticks))
                if bool(self.tick_live[row, t])]

    def row_accept_lens(self, row: int):
        """Accepted draft length per live tick of one row (see
        ``row_exit_points``)."""
        if not self.is_megatick:
            return [int(self.accept_len[row])]
        return [int(self.accept_len[row, t]) for t in range(int(self.ticks))
                if bool(self.tick_live[row, t])]
