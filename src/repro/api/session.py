"""Engine / DecodeSession — the one inference surface over every decode mode.

    engine = Engine.create(model, params, sw, strategy="tree")
    session = engine.new_session()
    first = session.prefill({"tokens": prompts}, max_new_tokens=64)
    while not session.all_done():
        res = session.step()            # canonical StepResult, any strategy

``Engine`` binds (model, params, SpecEE weights, strategy) and jits the
strategy step exactly once; sessions share the compiled step. A session owns
one batched ``DecodeState`` plus the host-side bookkeeping jit can't express:
per-row token budgets, EOS cut-off, and the ``done`` mask of the canonical
``StepResult``.

Two session styles:
  * whole-batch: ``prefill(prompts)`` then ``step()`` — examples, benchmarks;
  * slot-based (continuous batching): ``new_session(batch=B, max_seq=S)``
    pre-allocates empty rows; ``prefill_row(slot, prompt)`` admits a request
    into one row (batch-1 prefill + insert) while other rows keep decoding —
    the serving engine is a thin loop over exactly this.
"""
from __future__ import annotations

from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.models.model import Model

from repro.api.strategies import DecodeStrategy, get_strategy
from repro.api.types import StepResult

_NO_BUDGET = np.iinfo(np.int64).max


def _insert_row(big, small, row: int, batch: int):
    """Insert batch-1 pytree ``small`` as row ``row`` of batched ``big``."""
    def one(b, s):
        axis = None
        for i, (db, ds) in enumerate(zip(b.shape, s.shape)):
            if db == batch and ds == 1:
                axis = i
                break
        if axis is None and b.shape == s.shape:
            return b  # batch-independent leaf (e.g. PRNG key): keep
        assert axis is not None, f"no batch axis: {b.shape} vs {s.shape}"
        idx = [slice(None)] * b.ndim
        idx[axis] = row
        src = jnp.squeeze(s, axis=axis)
        return b.at[tuple(idx)].set(src.astype(b.dtype))
    return jax.tree_util.tree_map(one, big, small)


class Engine:
    """Binds a model + weights to a decode strategy; factory for sessions."""

    def __init__(self, model: Model, params, sw=None,
                 strategy: Union[str, DecodeStrategy, None] = None):
        self.model = model
        self.params = params
        self.sw = sw
        self.strategy = get_strategy(strategy)
        self.strategy.validate(model, sw)
        strat = self.strategy
        self._step_jit = jax.jit(
            lambda p, s, st: strat.step(model, p, s, st))

    @classmethod
    def create(cls, model: Model, params, sw=None,
               strategy: Union[str, DecodeStrategy, None] = None) -> "Engine":
        """The canonical constructor: ``Engine.create(model, params, sw,
        strategy="dense"|"specee"|"tree"|DecodeStrategy(...))``."""
        return cls(model, params, sw=sw, strategy=strategy)

    @property
    def emit_width(self) -> int:
        return self.strategy.emit_width(self.model)

    def new_session(self, batch: Optional[int] = None,
                    max_seq: Optional[int] = None,
                    prng_seed: int = 0) -> "DecodeSession":
        """``batch=None``: empty shell, populated by ``prefill(prompts)``.
        ``batch=B``: pre-allocated empty rows for slot-based serving
        (``max_seq`` defaults to the run's ``serve.max_seq_len``)."""
        return DecodeSession(self, batch=batch, max_seq=max_seq,
                             prng_seed=prng_seed)


class DecodeSession:
    def __init__(self, engine: Engine, batch: Optional[int] = None,
                 max_seq: Optional[int] = None, prng_seed: int = 0):
        self.engine = engine
        self._prng_seed = prng_seed
        self._max_seq = max_seq
        self._state: Optional[eng.DecodeState] = None
        self.batch: Optional[int] = None
        if batch is not None:
            if max_seq is None:
                max_seq = engine.model.run.serve.max_seq_len
                self._max_seq = max_seq
            self._state = engine.strategy.empty_state(
                engine.model, engine.sw, batch, max_seq,
                prng=jax.random.PRNGKey(prng_seed))
            self._alloc_bookkeeping(batch, live=False)

    # ----- host-side bookkeeping -----
    def _alloc_bookkeeping(self, batch: int, live: bool) -> None:
        self.batch = batch
        self._emitted = np.zeros(batch, np.int64)
        self._budget = np.full(batch, _NO_BUDGET, np.int64)
        self._eos: List[Optional[int]] = [None] * batch
        # empty slots count as done until a request is admitted
        self._done = np.full(batch, not live, bool)

    def _set_row_limits(self, row: int, max_new_tokens: Optional[int],
                        eos_token: Optional[int]) -> None:
        self._emitted[row] = 0
        self._budget[row] = (_NO_BUDGET if max_new_tokens is None
                             else max_new_tokens)
        self._eos[row] = eos_token
        self._done[row] = False

    def _account_row(self, row: int, toks: np.ndarray, count: int) -> int:
        """Apply budget + EOS to one row's raw emit; returns the kept count
        and updates ``done``/``emitted``."""
        if self._done[row]:
            return 0
        count = int(min(count, self._budget[row] - self._emitted[row]))
        eos = self._eos[row]
        if eos is not None:
            hits = np.nonzero(toks[:count] == eos)[0]
            if hits.size:
                count = int(hits[0]) + 1
                self._done[row] = True
        self._emitted[row] += count
        if self._emitted[row] >= self._budget[row]:
            self._done[row] = True
        return count

    def _wrap(self, raw: StepResult) -> StepResult:
        """Device → host + per-row budget/EOS accounting → canonical result."""
        tokens = np.asarray(raw.tokens)
        counts = np.asarray(raw.counts).copy()
        for row in range(tokens.shape[0]):
            counts[row] = self._account_row(row, tokens[row], counts[row])
        return StepResult(tokens=tokens, counts=counts,
                          done=self._done.copy(),
                          exit_layer=np.asarray(raw.exit_layer),
                          accept_len=np.asarray(raw.accept_len),
                          exited=np.asarray(raw.exited),
                          units_run=np.asarray(raw.units_run))

    def all_done(self) -> bool:
        return self._state is None or bool(self._done.all())

    def row_done(self, row: int) -> bool:
        return bool(self._done[row])

    def live_rows(self) -> np.ndarray:
        return ~self._done

    # ----- whole-batch entry -----
    def prefill(self, prompts, max_new_tokens: Optional[int] = None,
                eos_token: Optional[int] = None,
                max_seq: Optional[int] = None) -> StepResult:
        """Prefill the whole batch. ``prompts``: (B, T) int tokens or a
        ``{"tokens": ...}`` batch dict. Returns the first-token StepResult
        (the prefill's greedy argmax counts against the budget)."""
        e = self.engine
        batch = (dict(prompts) if isinstance(prompts, dict)
                 else {"tokens": jnp.asarray(prompts, jnp.int32)})
        B, T = batch["tokens"].shape
        if max_seq is None:
            max_seq = self._max_seq
        if max_seq is None:
            new = (max_new_tokens if max_new_tokens is not None
                   else e.model.run.serve.max_new_tokens)
            max_seq = T + new + e.emit_width + 1
        self._max_seq = max_seq
        first, self._state = e.strategy.init_state(
            e.model, e.params, e.sw, batch, max_seq,
            prng=jax.random.PRNGKey(self._prng_seed))
        self._alloc_bookkeeping(B, live=True)
        # the KV cache has max_seq slots: the budget is always bounded by the
        # remaining capacity so a budgetless session still terminates instead
        # of silently clobbering the last cache position
        cap = max(max_seq - T - 1, 1)
        budget = cap if max_new_tokens is None else min(max_new_tokens, cap)
        for row in range(B):
            self._set_row_limits(row, budget, eos_token)
        W, E = e.emit_width, e.model.num_exit_points
        raw = StepResult(
            tokens=jnp.pad(first[:, None], ((0, 0), (0, W - 1))),
            counts=jnp.ones((B,), jnp.int32),
            done=jnp.zeros((B,), bool),
            exit_layer=jnp.full((B,), E, jnp.int32),
            accept_len=jnp.zeros((B,), jnp.int32),
            exited=jnp.zeros((B,), bool),
            units_run=jnp.int32(0))
        return self._wrap(raw)

    # ----- slot-based entry (continuous batching) -----
    def prefill_row(self, row: int, prompt,
                    max_new_tokens: Optional[int] = None,
                    eos_token: Optional[int] = None) -> int:
        """Admit one request into slot ``row``: batch-1 prefill, insert the
        resulting rows into the batched state. Returns the first token."""
        assert self._state is not None and self.batch is not None, \
            "prefill_row needs a pre-allocated session (new_session(batch=B))"
        e = self.engine
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        first, st1 = e.strategy.init_state(e.model, e.params, e.sw,
                                           {"tokens": tokens}, self._max_seq)
        self._state = eng.DecodeState(*[
            _insert_row(big, small, row, self.batch)
            for big, small in zip(self._state, st1)])
        cap = max(self._max_seq - tokens.shape[1] - 1, 1)
        budget = cap if max_new_tokens is None else min(max_new_tokens, cap)
        self._set_row_limits(row, budget, eos_token)
        tok = int(first[0])
        n = self._account_row(row, np.asarray([tok]), 1)
        assert n <= 1
        return tok

    # ----- decode tick -----
    def step(self) -> StepResult:
        """One batched decode tick through the strategy's jitted step."""
        assert self._state is not None, "prefill first"
        e = self.engine
        raw, self._state = e._step_jit(e.params, e.sw, self._state)
        return self._wrap(raw)
