"""Engine / DecodeSession — the one inference surface over every decode mode.

    engine = Engine.create(model, params, sw, strategy="tree")
    session = engine.new_session()
    first = session.prefill({"tokens": prompts}, max_new_tokens=64)
    while not session.all_done():
        res = session.step()            # canonical StepResult, any strategy

``Engine`` binds (model, params, SpecEE weights, strategy) and jits the
strategy step exactly once; sessions share the compiled step. A session owns
one batched ``DecodeState`` plus per-row token budgets, EOS cut-off, and the
``done`` mask of the canonical ``StepResult``. For single steps that
bookkeeping runs host-side (the historical path, bit-preserved); for
``step(num_ticks=K)`` it moves INTO the jit as a device-resident carry so K
ticks run as one fused ``lax.while_loop`` ("megatick") with a single host
sync at the end — see DESIGN.md §6. The step/extend jits donate the decode
state (KV cache included, paged pools and page table too), so XLA updates
the cache in place instead of reallocating it every token; callers must not
read a state reference retained from before a step (donation deletes the
buffers loudly rather than corrupting them).

``step_async`` is the serving engine's pipelined variant: it dispatches a
megatick and returns a handle without blocking, keeping the budget/EOS/done
carry device-resident across megaticks so the NEXT megatick can dispatch
before the previous one's results are read (``finish_step`` syncs results +
host mirrors; admission/retirement between finish and the next dispatch
mirror their row edits onto the in-flight carry).

Session memory is owned by a ``KVCacheManager`` (``repro.api.cache``):
``new_session(..., cache="paged")`` swaps the slot-masked dense layout for
paged pools + a page table with zero changes to the step loop, and
``retire_row`` compacts a finished row (frees its pages, zeroes its length)
so idle slots stop paying attention span.

Two session styles:
  * whole-batch: ``prefill(prompts)`` then ``step()`` — examples, benchmarks;
  * slot-based (continuous batching): ``new_session(batch=B, max_seq=S)``
    pre-allocates empty rows; admission is either one-shot
    (``prefill_row(slot, prompt)``) or chunked Sarathi-style
    (``begin_admission`` + ``prefill_chunk``), which splits the prompt
    forward into fixed-token chunks so the serving loop can interleave them
    with decode ticks instead of stalling on long prompts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import draft as draft_lib
from repro.core import scheduler as sched_lib
from repro.models.model import Model
from repro.runtime import faultinject

from repro.api.cache import (CacheSpec, KVCacheManager, insert_row_pytree,
                             make_cache_manager)
from repro.api.strategies import DecodeStrategy, get_strategy
from repro.api.types import StepResult

_NO_BUDGET = np.iinfo(np.int64).max
_DEV_NO_BUDGET = np.iinfo(np.int32).max     # device-carry budget cap

# back-compat alias: the row-insert helper moved to repro.api.cache so the
# cache managers share it
_insert_row = insert_row_pytree


@dataclass
class MegatickHandle:
    """One dispatched-but-unread megatick (``DecodeSession.step_async``).

    ``out``/``carry`` hold device arrays that are still being computed;
    ``finish_step`` blocks on them. The carry captured here is the megatick's
    OUTPUT limits — the exact arrays the next megatick consumes as input.
    ``dirty`` collects rows whose HOST bookkeeping advanced after this
    dispatch (retire / re-admit mirror edits): for those rows the captured
    carry is stale, so ``finish_step`` keeps the host values instead of
    syncing from it.
    """
    out: Any
    carry: Any
    num_ticks: int
    dirty: set = field(default_factory=set)


class Engine:
    """Binds a model + weights to a decode strategy; factory for sessions."""

    def __init__(self, model: Model, params, sw=None,
                 strategy: Union[str, DecodeStrategy, None] = None,
                 quant=None, mesh=None, policy: str = "tp_dp"):
        self.model = model
        self.params = params
        self.sw = sw
        self.strategy = get_strategy(strategy)
        self.strategy.validate(model, sw)
        # tensor-parallel serving (DESIGN.md §9): a 2-D ("data","model") mesh
        # pins the weights with the Megatron-role specs and threads a static
        # ShardCtx into the jitted steps (sharded exit-gate verify). A mesh
        # whose 'model' extent is 1 degenerates to the unsharded path.
        self.mesh = mesh
        self.policy = policy
        self.shard = None
        if mesh is not None:
            from repro.sharding.ctx import ShardCtx
            self.shard = ShardCtx.from_mesh(mesh)
        # weight-only quantization (repro.quant): ``quant`` is a QuantSpec /
        # "int8" / "int4" / None. The quantized bundle is a PARALLEL pytree —
        # ``self.params`` stays untouched (paper: early exiting "without
        # affecting the model original parameters") and rides into the jitted
        # step as an extra argument so the kernels see the int tiles.
        from repro import quant as quant_lib
        self.quant_spec = quant_lib.QuantSpec.resolve(quant)
        self.qw = quant_lib.quantize_params(params, sw, self.quant_spec)
        if self.shard is not None:
            from repro.sharding import serving as shard_serving
            ps, ss, qs = shard_serving.engine_shardings(
                model, mesh, policy, self.params, self.sw, self.qw)
            self.params = jax.device_put(self.params, ps)
            if self.sw is not None:
                self.sw = jax.device_put(self.sw, ss)
            if self.qw is not None:
                self.qw = jax.device_put(self.qw, qs)
        self._prefill_view = None
        strat = self.strategy
        shard = self.shard
        # the decode state (KV cache pytree included — paged pools + page
        # table too) is DONATED: XLA updates the cache in place every tick
        # instead of reallocating it, and stale state references fail loudly
        self._step_jit = jax.jit(
            lambda p, s, st, qw: strat.step(model, p, s, st, qw=qw,
                                            shard=shard),
            donate_argnums=(2,))
        self._extend_jit = jax.jit(
            lambda p, toks, cache, n: model.prefill_extend(p, toks, cache, n),
            donate_argnums=(2,))
        self._mega_jits = {}

    def megatick_jit(self, num_ticks: int):
        """The jitted K-tick fused step (compiled once per K). The state —
        where the KV cache lives — is donated; the (B,)-sized limits carry is
        NOT: the async pipeline passes megatick N's output limits straight
        into megatick N+1 while N's handle still holds them for the deferred
        host sync, so donating them would delete buffers the finish path
        reads."""
        fn = self._mega_jits.get(num_ticks)
        if fn is None:
            strat, model, shard = self.strategy, self.model, self.shard
            fn = jax.jit(
                lambda p, s, st, limits, qw: strat.megatick(
                    model, p, s, st, limits, num_ticks, qw=qw, shard=shard),
                donate_argnums=(2,))
            self._mega_jits[num_ticks] = fn
        return fn

    @classmethod
    def create(cls, model: Model, params, sw=None,
               strategy: Union[str, DecodeStrategy, None] = None,
               quant=None, mesh=None, policy: str = "tp_dp") -> "Engine":
        """The canonical constructor: ``Engine.create(model, params, sw,
        strategy="dense"|"specee"|"tree"|DecodeStrategy(...),
        quant=None|"int8"|"int4"|QuantSpec(...),
        mesh=None|jax.sharding.Mesh)``. A mesh with a 'model' axis of
        extent > 1 turns on tensor-parallel decode (DESIGN.md §9)."""
        return cls(model, params, sw=sw, strategy=strategy, quant=quant,
                   mesh=mesh, policy=policy)

    @property
    def emit_width(self) -> int:
        return self.strategy.emit_width(self.model)

    def shard_state(self, state, cache_mgr=None):
        """Pin a ``DecodeState`` to the engine's mesh layout (no-op when
        unsharded). Sessions call this wherever a state is (re)built from
        host values — empty-state alloc, whole-batch prefill, row insert,
        restore — so the jitted step always sees one stable input layout
        (drifting shardings would fork the jit cache per layout)."""
        if self.shard is None:
            return state
        from repro.sharding import policies as pol
        from repro.sharding import serving as shard_serving
        specs = shard_serving.decode_state_specs(
            self.model, self.mesh, self.policy, state, cache_mgr=cache_mgr)
        return jax.device_put(state, pol.named(self.mesh, specs))

    def prefill_weights(self):
        """(params, sw) the prefill/admission paths consume.

        Under weight-only quantization the DECODE step sees the int tiles
        (dequant fused into the kernels); prefill must see the numerically
        identical dequantized weights, or the prefill-written KV cache and
        first token would come from the fp originals and diverge from what
        the quantized decode loop would have produced (visible at int4,
        where the quantization error is large enough to flip argmax). The
        dequantized view is materialized once and cached — prefill is the
        compute-bound cold path; the decode hot loop still runs on the
        compressed tiles."""
        if self.qw is None:
            return self.params, self.sw
        if self._prefill_view is None:
            from repro import quant as quant_lib
            self._prefill_view = quant_lib.dequantized_reference(
                self.params, self.sw, self.qw)
        return self._prefill_view

    def new_session(self, batch: Optional[int] = None,
                    max_seq: Optional[int] = None,
                    prng_seed: int = 0,
                    cache: Union[None, str, CacheSpec] = None
                    ) -> "DecodeSession":
        """``batch=None``: empty shell, populated by ``prefill(prompts)``.
        ``batch=B``: pre-allocated empty rows for slot-based serving
        (``max_seq`` defaults to the run's ``serve.max_seq_len``).
        ``cache``: "dense" (default) | "paged" | a ``CacheSpec`` — the
        KVCacheManager layout session memory lives in."""
        return DecodeSession(self, batch=batch, max_seq=max_seq,
                             prng_seed=prng_seed, cache=cache)


@dataclass
class Admission:
    """One in-flight chunked prefill (host-side handle).

    Created by ``DecodeSession.begin_admission``; each ``prefill_chunk`` call
    advances ``consumed`` by at most one chunk of prompt tokens. When the
    prompt is exhausted the session inserts the finished batch-1 state into
    ``row`` and ``first_token`` is set.
    """
    row: int
    tokens: np.ndarray
    max_new_tokens: Optional[int] = None
    eos_token: Optional[int] = None
    consumed: int = 0
    cache: Any = None               # batch-1 dense extend cache
    h_parts: List[Any] = field(default_factory=list)
    first_token: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def complete(self) -> bool:
        return self.first_token is not None

    @property
    def remaining(self) -> int:
        return self.prompt_len - self.consumed


class DecodeSession:
    def __init__(self, engine: Engine, batch: Optional[int] = None,
                 max_seq: Optional[int] = None, prng_seed: int = 0,
                 cache: Union[None, str, CacheSpec] = None):
        self.engine = engine
        self._prng_seed = prng_seed
        self._max_seq = max_seq
        self._cache_spec = CacheSpec.resolve(cache, engine.model.run.serve)
        self._state: Optional[eng.DecodeState] = None
        self.cache_mgr: Optional[KVCacheManager] = None
        self.batch: Optional[int] = None
        # device-resident decode limits (budget/emitted/eos/done/retired):
        # None = host bookkeeping is authoritative, rebuilt lazily at the
        # next megatick dispatch; non-None = carried device arrays threading
        # megatick→megatick (admission/retire mirror row edits onto them)
        self._dev_carry: Optional[dict] = None
        # dispatched-but-unread megaticks, oldest first (the async pipeline
        # dispatches N+1 before finishing N, so two can be outstanding)
        self._async_handles: List[MegatickHandle] = []
        if batch is not None:
            if max_seq is None:
                max_seq = engine.model.run.serve.max_seq_len
                self._max_seq = max_seq
            self.cache_mgr = self._make_manager(batch, max_seq)
            self._state = engine.shard_state(
                engine.strategy.empty_state(
                    engine.model, engine.sw, batch, max_seq,
                    prng=jax.random.PRNGKey(prng_seed),
                    cache=self.cache_mgr.empty_cache()),
                self.cache_mgr)
            self._alloc_bookkeeping(batch, live=False)

    def _make_manager(self, batch: int, max_seq: int) -> KVCacheManager:
        e = self.engine
        seq = e.strategy.cache_seq_len(e.model, max_seq)
        return make_cache_manager(e.model, batch, seq, self._cache_spec)

    # ----- host-side bookkeeping -----
    def _alloc_bookkeeping(self, batch: int, live: bool) -> None:
        self.batch = batch
        self._emitted = np.zeros(batch, np.int64)
        self._budget = np.full(batch, _NO_BUDGET, np.int64)
        self._eos: List[Optional[int]] = [None] * batch
        # empty slots count as done until a request is admitted
        self._done = np.full(batch, not live, bool)
        # rows compacted by retire_row: their logical length is pinned to 0
        # after every tick (the batched step advances len uniformly).
        # Never-admitted slots start retired too — without the pin their
        # cache["len"] creeps up every tick until it saturates the row's
        # paged capacity, and the degenerate attention at saturation
        # perturbs live rows through the batch-shared kernels (which breaks
        # the row-local determinism that eviction replay relies on)
        self._retired: set = set() if live else set(range(batch))
        self._dev_carry = None

    # ----- device-side decode-limit carry (megatick path) -----
    def _carry_from_host(self) -> dict:
        """Materialize the device-side limits from the host bookkeeping
        (dispatch-time lazy rebuild; 5 small (B,) transfers)."""
        B = self.batch
        retired = np.zeros(B, bool)
        if self._retired:
            retired[sorted(self._retired)] = True
        return {
            "budget": jnp.asarray(np.minimum(self._budget, _DEV_NO_BUDGET)
                                  .astype(np.int32)),
            "emitted": jnp.asarray(
                np.minimum(self._emitted, _DEV_NO_BUDGET).astype(np.int32)),
            "eos": jnp.asarray(np.asarray(
                [-1 if e is None else int(e) for e in self._eos], np.int32)),
            "done": jnp.asarray(self._done),
            "retired": jnp.asarray(retired),
        }

    def _mirror_row_to_dev(self, row: int) -> None:
        """Apply one row's host bookkeeping onto the in-flight device carry
        (enqueued .at ops, no sync) — admission/retirement between a megatick
        dispatch and the next must edit the carried arrays, not just the
        host mirrors the carry will overwrite at the next finish."""
        c = self._dev_carry
        if c is None:
            return
        eos = self._eos[row]
        self._dev_carry = {
            "budget": c["budget"].at[row].set(
                int(min(self._budget[row], _DEV_NO_BUDGET))),
            "emitted": c["emitted"].at[row].set(
                int(min(self._emitted[row], _DEV_NO_BUDGET))),
            "eos": c["eos"].at[row].set(-1 if eos is None else int(eos)),
            "done": c["done"].at[row].set(bool(self._done[row])),
            "retired": c["retired"].at[row].set(row in self._retired),
        }
        # outstanding megaticks were dispatched with a carry that predates
        # this edit: their finish must not roll the row's host mirrors back
        for h in self._async_handles:
            h.dirty.add(row)

    def _set_row_limits(self, row: int, max_new_tokens: Optional[int],
                        eos_token: Optional[int]) -> None:
        self._emitted[row] = 0
        self._budget[row] = (_NO_BUDGET if max_new_tokens is None
                             else max_new_tokens)
        self._eos[row] = eos_token
        self._done[row] = False

    def _account_row(self, row: int, toks: np.ndarray, count: int) -> int:
        """Apply budget + EOS to one row's raw emit; returns the kept count
        and updates ``done``/``emitted``."""
        if self._done[row]:
            return 0
        count = int(min(count, self._budget[row] - self._emitted[row]))
        eos = self._eos[row]
        if eos is not None:
            hits = np.nonzero(toks[:count] == eos)[0]
            if hits.size:
                count = int(hits[0]) + 1
                self._done[row] = True
        self._emitted[row] += count
        if self._emitted[row] >= self._budget[row]:
            self._done[row] = True
        return count

    def _wrap(self, raw: StepResult) -> StepResult:
        """Device → host + per-row budget/EOS accounting → canonical result.

        The single-tick path: accounting runs host-side, so any carried
        device limits are stale afterwards — drop them (the next megatick
        rebuilds from the host, which is authoritative here)."""
        self._dev_carry = None
        tokens = np.asarray(raw.tokens)
        counts = np.asarray(raw.counts).copy()
        for row in range(tokens.shape[0]):
            counts[row] = self._account_row(row, tokens[row], counts[row])
        return StepResult(tokens=tokens, counts=counts,
                          done=self._done.copy(),
                          exit_layer=np.asarray(raw.exit_layer),
                          accept_len=np.asarray(raw.accept_len),
                          exited=np.asarray(raw.exited),
                          units_run=np.asarray(raw.units_run))

    def all_done(self) -> bool:
        return self._state is None or bool(self._done.all())

    def row_done(self, row: int) -> bool:
        return bool(self._done[row])

    def live_rows(self) -> np.ndarray:
        return ~self._done

    # ----- cache management -----
    def can_admit(self, prompt_len: int = 0) -> bool:
        """Admission control: does the cache manager have room for one more
        request (paged: a full row reservation of free pages)?"""
        return self.cache_mgr is None or self.cache_mgr.can_admit(prompt_len)

    def retire_row(self, row: int) -> None:
        """Per-row compaction: release the finished row's cache footprint so
        the idle slot stops paying attention span (paged: pages return to
        the free list; dense: the logical length drops to zero).

        Safe under an in-flight megatick: the cache edits are functional ops
        enqueued on the in-flight output state (device ordering serializes
        them after the megatick's writes), and the row's done/retired bits
        are mirrored onto the carried limits so the NEXT megatick skips it."""
        assert self._state is not None and self.cache_mgr is not None
        self._done[row] = True
        self._retired.add(row)
        self._state = self._state._replace(
            cache=self.cache_mgr.retire_row(self._state.cache, row))
        self._mirror_row_to_dev(row)

    def row_span(self, row: int) -> int:
        """Attention span the row currently pays (tests/benchmarks)."""
        assert self._state is not None and self.cache_mgr is not None
        return self.cache_mgr.row_span(self._state.cache, row)

    # ----- checkpoint / restore / fault recovery (DESIGN.md §7) -----
    @property
    def in_flight(self) -> int:
        """Dispatched-but-unread async megaticks outstanding."""
        return len(self._async_handles)

    def abort_async(self) -> None:
        """Forget every dispatched-but-unread megatick (watchdog recovery).

        The host mirrors stay at their last *synced* values — which are
        authoritative precisely because the aborted megaticks' results were
        never read — and the device-limit carry is dropped, so the next
        dispatch rebuilds it from the host. ``self._state`` keeps pointing
        at the (still materializing) output buffers of the last dispatch;
        callers that suspect those values are poisoned must evict the
        affected rows, whose recompute replay rebuilds them from scratch.
        """
        self._async_handles.clear()
        self._dev_carry = None

    def snapshot(self) -> tuple:
        """-> ``(state_tree, meta)``: the full decode state of this session.

        ``state_tree`` is the device ``DecodeState`` pytree (KV pools + page
        table + draft cache + scheduler state + PRNG — everything the jitted
        step consumes); ``meta`` is a JSON-serializable dict of the host-side
        bookkeeping (budgets/emitted/eos/done/retired mirrors plus the cache
        manager's allocator state). Together they are sufficient for
        ``restore`` to resume decode token-identically. The caller must
        finish (or abort) outstanding async megaticks first — a snapshot
        straddling an unread dispatch would capture host mirrors that trail
        the device state.
        """
        assert self._state is not None and self.batch is not None, \
            "nothing to snapshot: session has no state"
        assert not self._async_handles, \
            "finish_step()/abort_async() outstanding megaticks before " \
            "snapshot()"
        meta = {
            "batch": int(self.batch),
            "max_seq": int(self._max_seq),
            "strategy": self.engine.strategy.name,
            "emitted": [int(x) for x in self._emitted],
            "budget": [None if int(b) >= _NO_BUDGET else int(b)
                       for b in self._budget],
            "eos": [None if e is None else int(e) for e in self._eos],
            "done": [bool(d) for d in self._done],
            "retired": sorted(int(r) for r in self._retired),
            "cache": self.cache_mgr.export_state(),
        }
        return self._state, meta

    def restore(self, state_tree, meta: dict) -> None:
        """Adopt a ``snapshot`` into THIS pre-allocated session.

        The session must have been built the same way as the one that
        snapshotted (same batch / max_seq / strategy / cache layout) —
        validated here before anything is touched. After restore the next
        ``step``/``step_async`` continues exactly where the saved session
        stopped (decode is deterministic: greedy argmax, and sampling keys
        derive from state that travels in the snapshot).
        """
        assert self._state is not None and self.batch is not None, \
            "restore needs a pre-allocated session (new_session(batch=B))"
        for key, have in (("batch", self.batch), ("max_seq", self._max_seq),
                          ("strategy", self.engine.strategy.name)):
            if meta[key] != have:
                raise ValueError(
                    f"snapshot {key}={meta[key]!r} does not match this "
                    f"session's {key}={have!r}")
        self.cache_mgr.import_state(meta["cache"])
        self._state = self.engine.shard_state(
            jax.tree_util.tree_map(jnp.asarray, state_tree), self.cache_mgr)
        self._emitted = np.asarray(meta["emitted"], np.int64)
        self._budget = np.asarray(
            [_NO_BUDGET if b is None else int(b) for b in meta["budget"]],
            np.int64)
        self._eos = [None if e is None else int(e) for e in meta["eos"]]
        self._done = np.asarray(meta["done"], bool)
        self._retired = set(int(r) for r in meta["retired"])
        self._dev_carry = None
        self._async_handles = []

    # ----- whole-batch entry -----
    def prefill(self, prompts, max_new_tokens: Optional[int] = None,
                eos_token: Optional[int] = None,
                max_seq: Optional[int] = None) -> StepResult:
        """Prefill the whole batch. ``prompts``: (B, T) int tokens or a
        ``{"tokens": ...}`` batch dict. Returns the first-token StepResult
        (the prefill's greedy argmax counts against the budget)."""
        e = self.engine
        batch = (dict(prompts) if isinstance(prompts, dict)
                 else {"tokens": jnp.asarray(prompts, jnp.int32)})
        B, T = batch["tokens"].shape
        if max_seq is None:
            max_seq = self._max_seq
        if max_seq is None:
            new = (max_new_tokens if max_new_tokens is not None
                   else e.model.run.serve.max_new_tokens)
            max_seq = T + new + e.emit_width + 1
        self._max_seq = max_seq
        pparams, psw = e.prefill_weights()
        first, self._state = e.strategy.init_state(
            e.model, pparams, psw, batch, max_seq,
            prng=jax.random.PRNGKey(self._prng_seed))
        self.cache_mgr = self._make_manager(B, max_seq)
        self._state = e.shard_state(
            self._state._replace(
                cache=self.cache_mgr.from_prefill(self._state.cache)),
            self.cache_mgr)
        self._alloc_bookkeeping(B, live=True)
        # the KV cache has max_seq slots: the budget is always bounded by the
        # remaining capacity so a budgetless session still terminates instead
        # of silently clobbering the last cache position
        cap = max(max_seq - T - 1, 1)
        budget = cap if max_new_tokens is None else min(max_new_tokens, cap)
        for row in range(B):
            self._set_row_limits(row, budget, eos_token)
        W, E = e.emit_width, e.model.num_exit_points
        raw = StepResult(
            tokens=jnp.pad(first[:, None], ((0, 0), (0, W - 1))),
            counts=jnp.ones((B,), jnp.int32),
            done=jnp.zeros((B,), bool),
            exit_layer=jnp.full((B,), E, jnp.int32),
            accept_len=jnp.zeros((B,), jnp.int32),
            exited=jnp.zeros((B,), bool),
            units_run=jnp.int32(0))
        return self._wrap(raw)

    # ----- slot-based admission (continuous batching) -----
    def _insert_state1(self, row: int, st1: eng.DecodeState, prompt_len: int,
                       max_new_tokens: Optional[int],
                       eos_token: Optional[int]) -> int:
        """Insert a finished batch-1 state into slot ``row`` (cache through
        the manager, the rest leaf-wise) + budget/EOS accounting. Returns the
        first token."""
        st = self._state
        self._retired.discard(row)
        cache = self.cache_mgr.insert_row(st.cache, row, st1.cache)
        B = self.batch
        self._state = eng.DecodeState(
            cache=cache,
            draft_cache=insert_row_pytree(st.draft_cache, st1.draft_cache,
                                          row, B),
            sched=insert_row_pytree(st.sched, st1.sched, row, B),
            last_token=insert_row_pytree(st.last_token, st1.last_token,
                                         row, B),
            h_last=insert_row_pytree(st.h_last, st1.h_last, row, B),
            prng=st.prng,
        )
        self._state = self.engine.shard_state(self._state, self.cache_mgr)
        cap = max(self._max_seq - prompt_len - 1, 1)
        budget = cap if max_new_tokens is None else min(max_new_tokens, cap)
        self._set_row_limits(row, budget, eos_token)
        tok = int(np.asarray(st1.last_token)[0])
        n = self._account_row(row, np.asarray([tok]), 1)
        assert n <= 1
        self._mirror_row_to_dev(row)
        return tok

    def prefill_row(self, row: int, prompt,
                    max_new_tokens: Optional[int] = None,
                    eos_token: Optional[int] = None) -> int:
        """Admit one request into slot ``row``: blocking batch-1 prefill,
        insert the resulting rows into the batched state. Returns the first
        token. (Chunked admission: ``begin_admission``/``prefill_chunk``.)"""
        assert self._state is not None and self.batch is not None, \
            "prefill_row needs a pre-allocated session (new_session(batch=B))"
        e = self.engine
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        pparams, psw = e.prefill_weights()
        first, st1 = e.strategy.init_state(e.model, pparams, psw,
                                           {"tokens": tokens}, self._max_seq)
        return self._insert_state1(row, st1, tokens.shape[1],
                                   max_new_tokens, eos_token)

    # ----- chunked admission (Sarathi-style) -----
    def begin_admission(self, row: int, prompt,
                        max_new_tokens: Optional[int] = None,
                        eos_token: Optional[int] = None) -> Admission:
        """Start admitting one request into slot ``row``. The returned handle
        is advanced by ``prefill_chunk`` — the prompt forward happens there,
        a chunk per call, so the caller can interleave decode ticks."""
        assert self._state is not None and self.batch is not None, \
            "begin_admission needs a pre-allocated session"
        return Admission(row=row, tokens=np.asarray(prompt, np.int64),
                         max_new_tokens=max_new_tokens, eos_token=eos_token)

    def prefill_chunk(self, adm: Admission,
                      max_tokens: Optional[int] = None) -> int:
        """Run at most ``max_tokens`` prompt tokens of ``adm``'s prefill.

        ``max_tokens=None`` (or an architecture without chunked-prefill
        support — recurrent/SSD/frontend stacks, DESIGN.md §4) falls back to
        the blocking one-shot path and completes the admission in one call.
        Returns the number of prompt tokens processed; when the prompt is
        exhausted the row is inserted and ``adm.first_token`` is set.
        """
        if adm.complete:
            return 0
        e = self.engine
        T = adm.prompt_len
        if max_tokens is None or not e.model.supports_chunked_prefill():
            assert adm.consumed == 0, \
                "cannot fall back to blocking admission mid-chunk"
            first = self.prefill_row(adm.row, adm.tokens,
                                     max_new_tokens=adm.max_new_tokens,
                                     eos_token=adm.eos_token)
            adm.consumed = T
            adm.first_token = first
            return T
        # chunked path: fixed-width padded chunk through the jitted extend
        C = int(max_tokens)
        if adm.cache is None:
            seq = e.strategy.cache_seq_len(e.model, self._max_seq)
            adm.cache = e.model.empty_cache(1, seq)
        n = min(C, adm.remaining)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = adm.tokens[adm.consumed:adm.consumed + n]
        pparams, _ = e.prefill_weights()
        h, adm.cache = e._extend_jit(pparams, jnp.asarray(chunk), adm.cache,
                                     jnp.int32(n))
        adm.h_parts.append(h[:, :n])
        adm.consumed += n
        if adm.remaining == 0:
            self._finish_admission(adm)
        return n

    def _finish_admission(self, adm: Admission) -> None:
        """Last chunk done: first token, draft prefill over the accumulated
        hiddens, batch-1 state assembly, row insert."""
        e = self.engine
        model = e.model
        params, sw = e.prefill_weights()
        tokens = jnp.asarray(adm.tokens, jnp.int32)[None, :]
        h_all = jnp.concatenate(adm.h_parts, axis=1)         # (1, T, D)
        logits = model.logits(params, h_all[:, -1, :])
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if sw is not None:
            seq = e.strategy.cache_seq_len(model, self._max_seq)
            embeds = model.embed(params, tokens)
            dcache = draft_lib.draft_prefill(model.cfg, sw.draft, embeds,
                                             h_all, seq)
        else:
            dcache = {}
        st1 = eng.DecodeState(
            cache=adm.cache,
            draft_cache=dcache,
            sched=sched_lib.init_state(1, model.run.specee),
            last_token=first,
            h_last=h_all[:, -1, :],
            prng=self._state.prng,
        )
        adm.first_token = self._insert_state1(
            adm.row, st1, adm.prompt_len, adm.max_new_tokens, adm.eos_token)
        adm.cache = None
        adm.h_parts = []

    # ----- decode tick -----
    def step(self, num_ticks: Optional[int] = None) -> StepResult:
        """Batched decode through the strategy's jitted step.

        ``num_ticks=None``/``1``: one tick, host-side budget/EOS accounting —
        the historical path, bit-preserved. ``num_ticks=K > 1``: one fused
        device-resident megatick (K ticks in one ``lax.while_loop`` with the
        accounting in the jitted carry and ONE host sync at the end) —
        token-identical to K single steps; the StepResult widens to the
        (B, K·W) megatick contract (see ``repro.api.types``).
        """
        assert self._state is not None, "prefill first"
        assert not self._async_handles, \
            "async megaticks are in flight; finish_step() them first"
        if num_ticks is None or int(num_ticks) == 1:
            e = self.engine
            # fault-injection site: fires BEFORE the donating jit call, so
            # the decode state is untouched and the caller may retry
            faultinject.check("dispatch")
            raw, self._state = e._step_jit(e.params, e.sw, self._state,
                                           e.qw)
            if self._retired:
                # compaction is sticky: the uniform len advance of the
                # batched step must not regrow a retired row's span
                cache = self._state.cache
                rows = jnp.asarray(sorted(self._retired), jnp.int32)
                self._state = self._state._replace(
                    cache=dict(cache, len=cache["len"].at[rows].set(0)))
            return self._wrap(raw)
        return self.finish_step(self.step_async(num_ticks))

    def step_async(self, num_ticks: int = 1) -> MegatickHandle:
        """Dispatch one megatick WITHOUT blocking on its results.

        The budget/EOS/done carry stays device-resident across async
        megaticks, so the caller may dispatch megatick N+1 before reading
        megatick N's results (the serving engine's pipeline) — correctness
        holds because the done mask travels in the carry, not on the host.
        Outstanding handles retire in dispatch order via ``finish_step``.
        """
        assert self._state is not None, "prefill first"
        K = int(num_ticks)
        assert K >= 1, f"num_ticks must be >= 1, got {K}"
        e = self.engine
        # fault-injection site: fires BEFORE the donating jit call, so the
        # decode state is untouched and the caller may retry the dispatch
        faultinject.check("dispatch")
        carry = (self._dev_carry if self._dev_carry is not None
                 else self._carry_from_host())
        out, self._state, carry = e.megatick_jit(K)(e.params, e.sw,
                                                    self._state, carry, e.qw)
        self._dev_carry = carry
        handle = MegatickHandle(out=out, carry=carry, num_ticks=K)
        self._async_handles.append(handle)
        return handle

    def finish_step(self, handle: MegatickHandle) -> StepResult:
        """Block on a dispatched megatick, sync host mirrors from its carry,
        and wrap the canonical (widened) StepResult. Handles finish oldest
        first (host mirrors advance monotonically through the pipeline), and
        finishing must precede any admission/retirement that reacts to the
        megatick's results."""
        assert self._async_handles and self._async_handles[0] is handle, \
            "megaticks finish in dispatch order (oldest handle first)"
        self._async_handles.pop(0)
        out = handle.out
        done = np.asarray(out["done"]).copy()
        emitted = np.asarray(handle.carry["emitted"]).astype(np.int64)
        # rows retired / re-admitted after this megatick's dispatch: the
        # host bookkeeping advanced past the dispatch-time carry — keep it
        # (the edit was mirrored onto the NEXT megatick's input, whose
        # finish will sync it back coherently)
        for row in handle.dirty:
            done[row] = self._done[row]
            emitted[row] = self._emitted[row]
        self._done = done
        self._emitted = emitted
        return StepResult(
            tokens=np.asarray(out["tokens"]),
            counts=np.asarray(out["counts"]),
            # the result's mask is the megatick's own dispatch-coherent view
            # (what _collect attributes to the dispatch-time slot snapshot),
            # not the merged host view — they differ only on dirty rows
            done=np.asarray(out["done"]),
            exit_layer=np.asarray(out["exit_layer"]),
            accept_len=np.asarray(out["accept_len"]),
            exited=np.asarray(out["exited"]),
            units_run=np.asarray(out["units_run"]),
            ticks=int(np.asarray(out["ticks"])),
            tick_counts=np.asarray(out["tick_counts"]),
            tick_live=np.asarray(out["tick_live"]))
