"""Pluggable decode strategies — adapters from the jittable step functions
(`repro.core.engine`, the kernels-of-record) to the canonical ``StepResult``.

A strategy owns everything mode-specific: how the state is initialized (the
tree mode reserves scratch cache slots), how wide a step's emit can be, and
which engine step runs per tick. Exit-gate backend selection
(``ModelFlags.exit_gate_kernel``) resolves INSIDE the engine entry points via
``exit_gate.ops.impl_for_flags`` — callers of this API never touch it.

``strategy.step`` is pure and jit-compatible: ``DecodeSession`` jits it once;
``launch/dryrun.py`` lowers it against the production mesh as-is.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.tree import TreeSpec
from repro.models.model import Model

from repro.api.types import StepResult


def _no_done(B: int):
    return jnp.zeros((B,), bool)


def _single_token_result(token, info: eng.StepInfo) -> StepResult:
    """Pack a 1-token-per-tick engine emit (dense / AR) as a StepResult."""
    B = token.shape[0]
    return StepResult(tokens=token[:, None],
                      counts=jnp.ones((B,), jnp.int32),
                      done=_no_done(B),
                      exit_layer=info.exit_point,
                      accept_len=jnp.zeros((B,), jnp.int32),
                      exited=info.exited,
                      units_run=info.units_run)


@dataclass(frozen=True)
class DecodeStrategy:
    """Base: one decode mode behind the Engine/DecodeSession surface."""
    name = "base"
    requires_sw = True

    def emit_width(self, model: Model) -> int:
        return 1

    def cache_seq_len(self, model: Model, max_seq: int) -> int:
        """State slots to allocate for a ``max_seq`` session (tree mode
        reserves its node-scratch region on top)."""
        return max_seq

    def validate(self, model: Model, sw) -> None:
        if self.requires_sw and sw is None:
            raise ValueError(f"{type(self).__name__} needs SpecEE weights "
                             "(draft + predictors); pass sw=")

    def init_state(self, model: Model, params, sw,
                   batch: Dict[str, jnp.ndarray], max_seq: int, prng=None
                   ) -> Tuple[jnp.ndarray, eng.DecodeState]:
        """Prefill → (first greedy token (B,), state). ``max_seq`` is the
        SESSION length; strategies add their own scratch internally."""
        return eng.init_decode_state(model, params, sw, batch,
                                     self.cache_seq_len(model, max_seq),
                                     prng=prng)

    def empty_state(self, model: Model, sw, batch: int, max_seq: int,
                    prng=None, cache=None) -> eng.DecodeState:
        """``cache``: a pre-built cache pytree from the session's
        ``KVCacheManager`` (dense or paged layout); None keeps the dense
        allocation. The strategy step functions read the layout off the
        state itself (``cache["page_table"]``), so one jitted step serves
        both."""
        return eng.empty_decode_state(model, sw, batch,
                                      self.cache_seq_len(model, max_seq),
                                      prng=prng, cache=cache)

    def step(self, model: Model, params, sw, state: eng.DecodeState,
             qw=None, shard=None) -> Tuple[StepResult, eng.DecodeState]:
        """``qw``: optional quantized-weight bundle
        (``repro.quant.quantize_params``) threaded into the engine step —
        a parallel pytree; the original ``params`` stay untouched.
        ``shard``: optional ``repro.sharding.ctx.ShardCtx`` — the engine
        runs its full-LM-head reductions as per-shard partials (DESIGN.md
        §9); threaded statically from ``Engine`` (it keys the jit cache)."""
        raise NotImplementedError

    def megatick(self, model: Model, params, sw, state: eng.DecodeState,
                 limits, num_ticks: int, qw=None, shard=None):
        """Fuse ``num_ticks`` strategy steps into one device-resident
        ``lax.while_loop`` (``engine.megatick_decode``): per-row budgets, EOS
        cut-off, and the done mask ride in the jitted carry, so host sync
        happens once per megatick instead of once per tick. Works unchanged
        for every strategy — the adapter below is the only mode-specific
        glue. Returns ``(out dict, new_state, new_limits)``."""
        def tick(st):
            res, new_st = self.step(model, params, sw, st, qw=qw,
                                    shard=shard)
            return eng.TickEmit(tokens=res.tokens, counts=res.counts,
                                exit_layer=res.exit_layer,
                                accept_len=res.accept_len,
                                exited=res.exited,
                                units_run=res.units_run), new_st
        return eng.megatick_decode(tick, state, limits, num_ticks,
                                   self.emit_width(model),
                                   model.num_exit_points)


@dataclass(frozen=True)
class DenseStrategy(DecodeStrategy):
    """Full-depth baseline. Greedy by default; ``temperature > 0`` samples
    from the full logits, consuming the session's PRNG stream (seeded via
    ``Engine.new_session(prng_seed=...)`` / ``ServingEngine(prng_seed=...)``).
    """
    temperature: float = 0.0
    top_k: Optional[int] = None
    name = "dense"
    requires_sw = False

    def step(self, model, params, sw, state, qw=None, shard=None):
        token, new_state, info = eng.dense_decode_step(
            model, params, sw, state, temperature=self.temperature,
            top_k=self.top_k, qw=qw, shard=shard)
        return _single_token_result(token, info), new_state


@dataclass(frozen=True)
class SpecEEStrategy(DecodeStrategy):
    """Autoregressive speculative early exiting (paper T1+T2).

    ``threshold=None`` takes ``run.specee.exit_threshold``; a threshold > 1
    disables exits (bit-identical to dense greedy — the property the
    session-level parity tests pin).
    """
    threshold: Optional[float] = None
    name = "specee"

    def step(self, model, params, sw, state, qw=None, shard=None):
        token, new_state, info = eng.ar_decode_step(
            model, params, sw, state, threshold=self.threshold, qw=qw,
            shard=shard)
        return _single_token_result(token, info), new_state


@dataclass(frozen=True)
class TreeStrategy(DecodeStrategy):
    """T3: tree speculative decoding with the hyper-token merged mapping.

    Emits up to ``tree.depth + 1`` tokens per tick (accepted chain + bonus).
    ``tree=None`` builds the TreeSpec from ``run.specee.tree_depth/_branch``.
    """
    tree: Optional[TreeSpec] = None
    threshold: Optional[float] = None
    name = "tree"

    def tree_for(self, model: Model) -> TreeSpec:
        if self.tree is not None:
            return self.tree
        spec = model.run.specee
        return TreeSpec(depth=spec.tree_depth, branch=spec.tree_branch)

    def emit_width(self, model):
        return self.tree_for(model).depth + 1

    def cache_seq_len(self, model, max_seq):
        return max_seq + self.tree_for(model).num_nodes

    def validate(self, model, sw):
        super().validate(model, sw)
        if not model.supports_tree():
            raise ValueError(
                "tree strategy requires a pure-attention stack (DESIGN.md "
                f"§4); {model.cfg.name} is {model.cfg.family}")
        if model.flags.kv_quant:
            raise ValueError(
                "tree strategy does not support kv_quant: tree scratch "
                "writes are full-precision (the node K/V is re-read within "
                "the same step, where int8 round-tripping would corrupt "
                "verification); decode with the AR engine instead "
                "(DESIGN.md §4)")

    def step(self, model, params, sw, state, qw=None, shard=None):
        out, n_emit, new_state, info = eng.tree_decode_step(
            model, params, sw, state, self.tree_for(model),
            threshold=self.threshold, qw=qw, shard=shard)
        B = out.shape[0]
        res = StepResult(tokens=out,
                         counts=n_emit.astype(jnp.int32),
                         done=_no_done(B),
                         exit_layer=info.exit_point,
                         accept_len=info.accepted_len,
                         exited=info.exited,
                         units_run=info.units_run)
        return res, new_state


_BY_NAME = {
    "dense": DenseStrategy,
    "specee": SpecEEStrategy,
    "ar": SpecEEStrategy,
    "tree": TreeStrategy,
}


def get_strategy(spec: Union[str, DecodeStrategy, None]) -> DecodeStrategy:
    """Resolve a strategy name or pass an instance through.

    Names: "dense" | "specee" (alias "ar") | "tree".
    """
    if spec is None:
        return SpecEEStrategy()
    if isinstance(spec, DecodeStrategy):
        return spec
    try:
        return _BY_NAME[spec]()
    except KeyError:
        raise ValueError(
            f"unknown strategy {spec!r}; expected one of {sorted(_BY_NAME)} "
            "or a DecodeStrategy instance") from None
