"""Chunked-prefill admission scheduler (Sarathi-style iteration scheduling).

The serving loop's admission policy, factored out of the engine: arriving
requests queue here, and every decode tick the scheduler runs a *bounded*
amount of prefill work before the batched strategy step:

* **chunked** (``chunk_tokens=N``): prompts are split into fixed N-token
  chunks behind ``DecodeSession.prefill_chunk``. While any decode row is
  live, a tick runs AT MOST one chunk — live rows are never stalled for more
  than one chunk budget per tick (the Sarathi interleaving invariant, tested
  in tests/test_paged_cache.py). With no live rows the scheduler drains
  freely (pure admission phase, nothing to stall).
* **blocking** (``chunk_tokens=None``): the historical behavior — each free
  slot admits with one whole-prompt prefill inside the tick.

Admission is additionally gated by the session's ``KVCacheManager``
(``session.can_admit``): a paged pool without a free row reservation defers
the queue head instead of overcommitting memory.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.api.session import Admission, DecodeSession


@dataclass
class Admitted:
    """One admission completed this tick: the row is live (or already done —
    budget 0 / first token hit EOS; the caller checks ``session.row_done``)."""
    uid: int
    row: int
    first_token: int


@dataclass
class _Pending:
    uid: int
    prompt: np.ndarray
    max_new_tokens: Optional[int]
    eos_token: Optional[int]


class ChunkedPrefillScheduler:
    """Owns the pending queue + the (single) in-flight chunked admission."""

    def __init__(self, session: DecodeSession,
                 chunk_tokens: Optional[int] = None):
        if chunk_tokens is not None and chunk_tokens <= 0:
            raise ValueError(
                f"chunk_tokens must be > 0 or None (blocking), got "
                f"{chunk_tokens}")
        self.session = session
        self.chunk_tokens = chunk_tokens
        self.queue: Deque[_Pending] = deque()
        self._active: Optional[Tuple[int, Admission]] = None
        self.last_tick_tokens = 0       # prefill tokens run by the last tick
        # consecutive ticks the queue head sat blocked on ``can_admit`` while
        # a free slot was available — the serving engine's pool-pressure
        # signal (``>= evict_patience`` triggers victim eviction, DESIGN §7)
        self.deferred_ticks = 0

    # ----- intake -----
    def submit(self, uid: int, prompt, max_new_tokens: Optional[int] = None,
               eos_token: Optional[int] = None) -> None:
        self.queue.append(_Pending(uid, np.asarray(prompt),
                                   max_new_tokens, eos_token))

    # ----- introspection -----
    def busy_rows(self) -> Set[int]:
        """Rows reserved by an in-flight (multi-tick) admission."""
        return set() if self._active is None else {self._active[1].row}

    def has_work(self) -> bool:
        return bool(self.queue) or self._active is not None

    @property
    def queued(self) -> List[int]:
        return [p.uid for p in self.queue]

    @property
    def admitting(self) -> List[int]:
        """Uid of the in-flight (multi-tick) admission, if any — still
        "pending" from the caller's point of view: not yet slotted."""
        return [] if self._active is None else [self._active[0]]

    # ----- one tick of admission work -----
    def tick(self, free_rows: Sequence[int],
             live_decode: bool = True) -> List[Admitted]:
        """Run admission work for one engine tick.

        ``free_rows``: slots available for new admissions (the caller
        excludes rows it considers occupied; in-flight rows are excluded here
        via ``busy_rows``). ``live_decode``: whether any decode row is live —
        if so, chunked mode runs at most ONE chunk this tick so decode is
        never stalled longer than one chunk budget.
        """
        events: List[Admitted] = []
        free = [r for r in free_rows if r not in self.busy_rows()]
        self.last_tick_tokens = 0
        deferred = False
        while True:
            if self._active is None:
                if not self.queue or not free:
                    break
                head = self.queue[0]
                if not self.session.can_admit(len(head.prompt)):
                    deferred = True
                    break               # paged pool full: defer admission
                self.queue.popleft()
                row = free.pop(0)
                adm = self.session.begin_admission(
                    row, head.prompt, max_new_tokens=head.max_new_tokens,
                    eos_token=head.eos_token)
                self._active = (head.uid, adm)
            uid, adm = self._active
            n = self.session.prefill_chunk(adm, self.chunk_tokens)
            self.last_tick_tokens += n
            if adm.complete:
                events.append(Admitted(uid=uid, row=adm.row,
                                       first_token=adm.first_token))
                self._active = None
            if live_decode and self.chunk_tokens is not None:
                break                   # one chunk per live tick, max
        # pressure signal: stuck means a slot was free but the pool refused
        # the head AND nothing else was admitted this tick (an admission
        # elsewhere is forward progress, so the counter restarts)
        if deferred and not events:
            self.deferred_ticks += 1
        else:
            self.deferred_ticks = 0
        return events

    def remove(self, uid: int) -> bool:
        """Withdraw a queued request (deadline shedding / cancel). Only the
        queue is searched — abort the in-flight admission first if it holds
        the uid (``abort_active`` requeues it here). Returns True when the
        uid was queued."""
        for p in list(self.queue):
            if p.uid == uid:
                self.queue.remove(p)
                return True
        return False

    def abort_active(self) -> Optional[int]:
        """Abort the in-flight chunked admission, requeueing its request at
        the queue FRONT (it keeps its turn). Safe at any point mid-prefill:
        no session row or page is claimed until the admission's final chunk
        inserts the row, so the partial prefill work is simply dropped and a
        later tick (possibly in a restored process) re-runs it from scratch.
        Returns the requeued uid, or None if nothing was in flight."""
        if self._active is None:
            return None
        uid, adm = self._active
        self._active = None
        self.queue.appendleft(_Pending(uid, np.asarray(adm.tokens),
                                       adm.max_new_tokens, adm.eos_token))
        return uid
