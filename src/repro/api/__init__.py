"""Unified decode API: one engine surface for dense / AR-SpecEE / tree.

The paper's merged-mapping insight — "different decoding methods share the
same essential characteristics" — lifted into the public API:

    from repro.api import Engine

    engine = Engine.create(model, params, sw, strategy="specee")
    session = engine.new_session()
    res = session.prefill(prompts, max_new_tokens=64)     # StepResult
    while not session.all_done():
        res = session.step()                              # StepResult

Strategies are pluggable (``DenseStrategy``, ``SpecEEStrategy``,
``TreeStrategy`` or any ``DecodeStrategy`` subclass); the step functions in
``repro.core.engine`` remain the jittable kernels-of-record underneath.

Session memory and admission are first-class (PR 3):
``repro.api.cache`` owns the KV layout (``KVCacheManager``: paged pools +
page table, or the bit-identical dense reference) and
``repro.api.scheduler`` owns admission (``ChunkedPrefillScheduler``:
Sarathi-style chunked prefill interleaved with decode ticks). The serving
engine (``repro.serving``) composes exactly these; see docs/api.md for the
migration table from the old direct step-function calls and from
``prefill_row``-only admission.
"""
from repro.api.cache import (CacheSpec, DenseKVCache, KVCacheManager,
                             PagedKVCache, make_cache_manager)
from repro.api.scheduler import Admitted, ChunkedPrefillScheduler
from repro.api.session import (Admission, DecodeSession, Engine,
                               MegatickHandle)
from repro.api.strategies import (DecodeStrategy, DenseStrategy,
                                  SpecEEStrategy, TreeStrategy, get_strategy)
from repro.api.types import StepResult
from repro.quant import QuantSpec

__all__ = [
    "Engine", "DecodeSession", "StepResult", "DecodeStrategy",
    "DenseStrategy", "SpecEEStrategy", "TreeStrategy", "get_strategy",
    "CacheSpec", "KVCacheManager", "DenseKVCache", "PagedKVCache",
    "make_cache_manager", "ChunkedPrefillScheduler", "Admitted", "Admission",
    "MegatickHandle", "QuantSpec",
]
