"""Unified decode API: one engine surface for dense / AR-SpecEE / tree.

The paper's merged-mapping insight — "different decoding methods share the
same essential characteristics" — lifted into the public API:

    from repro.api import Engine

    engine = Engine.create(model, params, sw, strategy="specee")
    session = engine.new_session()
    res = session.prefill(prompts, max_new_tokens=64)     # StepResult
    while not session.all_done():
        res = session.step()                              # StepResult

Strategies are pluggable (``DenseStrategy``, ``SpecEEStrategy``,
``TreeStrategy`` or any ``DecodeStrategy`` subclass); the step functions in
``repro.core.engine`` remain the jittable kernels-of-record underneath. The
serving engine (``repro.serving``) is a thin continuous-batching loop over
``DecodeSession``; see docs/api.md for the migration table from the old
direct step-function calls.
"""
from repro.api.session import DecodeSession, Engine
from repro.api.strategies import (DecodeStrategy, DenseStrategy,
                                  SpecEEStrategy, TreeStrategy, get_strategy)
from repro.api.types import StepResult

__all__ = [
    "Engine", "DecodeSession", "StepResult", "DecodeStrategy",
    "DenseStrategy", "SpecEEStrategy", "TreeStrategy", "get_strategy",
]
