"""KVCacheManager — session memory as a managed surface.

The decode state's KV cache stops being a raw pytree the session threads
around and becomes an object with an owner: a ``KVCacheManager`` builds the
cache, admits rows into it, and retires them out of it. Two implementations
share the surface:

* ``DenseKVCache`` — the historical slot-masked ``(B, max_seq, ...)`` layout,
  preserved bit-for-bit. It is the numerics reference the paged layout is
  property-tested against.
* ``PagedKVCache`` — vLLM-style paged memory adapted to JAX's static shapes:
  every attention entry stores K/V (and int8 scales under ``kv_quant``) in a
  per-layer *page pool* leaf ``(num_pages + 1, page_size, ...)``; one
  ``page_table (B, pages_per_row)`` int32, shared by all layers, maps each
  row's logical pages to physical ids (the ``+1`` page is a write-only trash
  page that unallocated/retired rows alias). A host-side free-page list backs
  admission control (``can_admit``), and per-row compaction
  (``retire_row``) frees a finished row's pages and zeroes its logical
  length, so a long-idle slot stops paying attention span the moment it
  retires instead of dragging its stale context through every tick.

Recurrent/SSD entries (per-row O(1) states, no sequence axis) are never
paged; hybrid and SSM architectures get paged attention entries next to
dense recurrent ones, so the manager works for every arch in the zoo.

Allocation is deliberately reservation-based: a row's full
``pages_per_row`` worth of pages is claimed at admission and returned at
retirement. The jitted step functions never allocate — they only index
through an already-valid table (``repro.core.paged``), which keeps them pure
and keeps paged decode bit-identical to the dense reference.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ATTN, LOCAL_ATTN
from repro.core import paged as paged_lib
from repro.runtime import faultinject


@dataclass(frozen=True)
class CacheSpec:
    """How a session's KV memory is laid out.

    kind: "dense" (slot-masked reference) | "paged" (page pool + table).
    page_size: tokens per page (paged only); ``ServeConfig.page_size``
        validates the serving default at config construction.
    num_pages: physical pages per layer pool. None = ``batch *
        pages_per_row`` (capacity parity with the dense layout); smaller
        values oversubscribe the pool and make ``can_admit`` a real gate.
    """
    kind: str = "dense"
    page_size: int = 128
    num_pages: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in ("dense", "paged"):
            raise ValueError(
                f"CacheSpec.kind must be 'dense' or 'paged', got {self.kind!r}")
        if self.page_size <= 0:
            raise ValueError(
                f"CacheSpec.page_size must be > 0, got {self.page_size}")

    @staticmethod
    def resolve(spec: Union[None, str, "CacheSpec"],
                serve_cfg=None) -> "CacheSpec":
        """None -> dense (back-compat); "dense"/"paged" -> spec with the
        run's ``ServeConfig.page_size``; a CacheSpec passes through."""
        if isinstance(spec, CacheSpec):
            return spec
        if spec is None:
            spec = "dense"
        page = serve_cfg.page_size if serve_cfg is not None else 128
        return CacheSpec(kind=spec, page_size=page)


def insert_row_pytree(big, small, row: int, batch: int):
    """Insert batch-1 pytree ``small`` as row ``row`` of batched ``big``.

    The historical ``DecodeSession`` row-insert: the batch axis of each leaf
    is found by matching ``batch`` vs 1 dims; batch-independent leaves (PRNG
    key) pass through. Lives here so both the session (non-cache state) and
    ``DenseKVCache.insert_row`` share one definition.
    """
    def one(b, s):
        axis = None
        for i, (db, ds) in enumerate(zip(b.shape, s.shape)):
            if db == batch and ds == 1:
                axis = i
                break
        if axis is None and b.shape == s.shape:
            return b  # batch-independent leaf (e.g. PRNG key): keep
        assert axis is not None, f"no batch axis: {b.shape} vs {s.shape}"
        idx = [slice(None)] * b.ndim
        idx[axis] = row
        src = jnp.squeeze(s, axis=axis)
        return b.at[tuple(idx)].set(src.astype(b.dtype))
    return jax.tree_util.tree_map(one, big, small)


class KVCacheManager:
    """Owner of one session's KV memory: layout, admission, compaction."""

    kind = "base"

    def __init__(self, model, batch: int, seq_len: int, spec: CacheSpec):
        self.model = model
        self.batch = batch
        self.seq_len = seq_len          # requested logical capacity per row
        self.spec = spec

    # ----- layout -----
    def empty_cache(self) -> Any:
        raise NotImplementedError

    def from_prefill(self, dense_cache: Any) -> Any:
        """Adopt a whole-batch dense prefill cache (``model.prefill``'s
        output) into this manager's layout."""
        raise NotImplementedError

    # ----- admission / retirement -----
    def insert_row(self, cache: Any, row: int, row_cache: Any) -> Any:
        """Admit a batch-1 dense cache (one prefilled request) into ``row``."""
        raise NotImplementedError

    def retire_row(self, cache: Any, row: int) -> Any:
        """Per-row compaction: drop the row's logical length (and, when
        paged, return its pages to the free list) so the idle slot's
        attention span collapses to zero."""
        raise NotImplementedError

    def can_admit(self, prompt_len: int = 0) -> bool:
        """Admission gate. The ``pool_exhausted`` fault-injection site lives
        here so a seeded schedule can simulate a dry pool on any layout —
        driving the serving engine's victim-eviction path deterministically
        (repro.runtime.faultinject)."""
        if faultinject.fire("pool_exhausted"):
            return False
        return self._can_admit(prompt_len)

    def _can_admit(self, prompt_len: int = 0) -> bool:
        return True

    # ----- checkpoint / restore (DESIGN.md §7) -----
    def export_state(self) -> dict:
        """Host-side allocator state for a session snapshot (the device
        arrays — pools, page table — travel in the DecodeState pytree)."""
        return {"kind": self.kind}

    def import_state(self, st: dict) -> None:
        """Adopt a snapshot's allocator state. The manager must have been
        built with the same layout the snapshot was taken under."""
        if st.get("kind") != self.kind:
            raise ValueError(
                f"cache snapshot is {st.get('kind')!r}, manager is "
                f"{self.kind!r} — restore needs the same cache layout")

    # ----- mesh layout (DESIGN.md §9) -----
    def partition_specs(self, cache: Any, mesh, policy: str = "tp_dp") -> Any:
        """PartitionSpec tree describing how this manager's cache pytree
        lays out on ``mesh`` (tensor-parallel serving): KV sharded on the
        head dim over 'model', bookkeeping replicated. The default delegates
        to the Megatron-role cache rules (``sharding/policies.cache_specs``
        with the sequence split off — decode scatters positions
        dynamically)."""
        from repro.sharding import policies as pol
        return pol.cache_specs(self.model, mesh, policy, cache,
                               kv_seq_shard=False)

    # ----- introspection (tests / benchmarks) -----
    def row_span(self, cache: Any, row: int) -> int:
        """Attention span the row currently pays (valid cache positions)."""
        return int(np.asarray(cache["len"])[row])

    def row_pages(self, row: int) -> int:
        return 0

    @property
    def free_pages(self) -> int:
        return 0

    @property
    def capacity(self) -> int:
        return self.seq_len

    def _attention_units(self):
        for seg, (unit, _reps) in enumerate(self.model.segments):
            for i, kind in enumerate(unit):
                yield seg, f"u{i}", kind in (ATTN, LOCAL_ATTN)


class DenseKVCache(KVCacheManager):
    """Bit-identical reference: the historical slot-masked dense layout."""

    kind = "dense"

    def empty_cache(self) -> Any:
        return self.model.empty_cache(self.batch, self.seq_len)

    def from_prefill(self, dense_cache: Any) -> Any:
        return dense_cache

    def insert_row(self, cache: Any, row: int, row_cache: Any) -> Any:
        return insert_row_pytree(cache, row_cache, row, self.batch)

    def retire_row(self, cache: Any, row: int) -> Any:
        return dict(cache, len=cache["len"].at[row].set(0))


class PagedKVCache(KVCacheManager):
    """Paged layout: per-layer page pools + one shared page table."""

    kind = "paged"

    def __init__(self, model, batch: int, seq_len: int, spec: CacheSpec):
        super().__init__(model, batch, seq_len, spec)
        ps = spec.page_size
        self.page_size = ps
        self.pages_per_row = -(-seq_len // ps)
        self.num_pages = (spec.num_pages if spec.num_pages is not None
                          else batch * self.pages_per_row)
        if self.num_pages < self.pages_per_row:
            raise ValueError(
                f"paged cache pool of {self.num_pages} pages cannot hold even "
                f"one row ({self.pages_per_row} pages/row)")
        self.trash_page = self.num_pages        # extra write-only page
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._row_pages: List[List[int]] = [[] for _ in range(batch)]

    @property
    def capacity(self) -> int:
        """Logical per-row capacity (rounded up to whole pages)."""
        return self.pages_per_row * self.page_size

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def row_pages(self, row: int) -> int:
        return len(self._row_pages[row])

    def _can_admit(self, prompt_len: int = 0) -> bool:
        return len(self._free) >= self.pages_per_row

    def export_state(self) -> dict:
        return {"kind": self.kind, "page_size": self.page_size,
                "num_pages": self.num_pages,
                "free": [int(p) for p in self._free],
                "row_pages": [[int(p) for p in r] for r in self._row_pages]}

    def import_state(self, st: dict) -> None:
        super().import_state(st)
        if (st["page_size"] != self.page_size
                or st["num_pages"] != self.num_pages):
            raise ValueError(
                f"paged snapshot geometry (page_size={st['page_size']}, "
                f"num_pages={st['num_pages']}) does not match manager "
                f"(page_size={self.page_size}, num_pages={self.num_pages})")
        self._free = [int(p) for p in st["free"]]
        self._row_pages = [[int(p) for p in r] for r in st["row_pages"]]

    # ----- layout -----
    def empty_cache(self) -> Any:
        from repro.models.model import _empty_cache_entry
        m, cfg = self.model, self.model.cfg
        from repro.models import common
        dtype = common.dtype_of(cfg.dtype)
        segs = []
        for unit, reps in m.segments:
            entry = {}
            for i, kind in enumerate(unit):
                if kind in (ATTN, LOCAL_ATTN):
                    # pool leaves: (num_pages + 1, page_size, ...) — the last
                    # page is the trash page unallocated rows alias
                    one = _empty_cache_entry(cfg, kind, self.num_pages + 1,
                                             self.page_size, dtype,
                                             m.flags.kv_quant)
                else:
                    one = _empty_cache_entry(cfg, kind, self.batch,
                                             self.page_size, dtype,
                                             m.flags.kv_quant)
                entry[f"u{i}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape),
                    one)
            segs.append(entry)
        table = jnp.full((self.batch, self.pages_per_row), self.trash_page,
                         jnp.int32)
        return {"segments": segs,
                "len": jnp.zeros((self.batch,), jnp.int32),
                "page_table": table}

    def partition_specs(self, cache: Any, mesh, policy: str = "tp_dp") -> Any:
        """Head-sharded paged layout: attention pool leaves shard their
        KV-head dim over 'model' (``core.paged.pool_partition_dims`` — page
        ids index the leading dims, so pages/page_size stay whole); the page
        table, lengths, and non-attention entries are replicated — every
        shard resolves the same page indirection."""
        from jax.sharding import PartitionSpec as P
        from repro.core import paged as paged_lib
        M = int(dict(mesh.shape).get("model", 1))
        rep = lambda x: P(*([None] * np.ndim(x)))  # noqa: E731
        attn = {(seg, key): is_attn
                for seg, key, is_attn in self._attention_units()}
        segs = []
        for seg, entry in enumerate(cache["segments"]):
            out = {}
            for key, sub in entry.items():
                if attn.get((seg, key)):
                    out[key] = jax.tree_util.tree_map(
                        lambda x: P(*paged_lib.pool_partition_dims(
                            np.shape(x), M)), sub)
                else:
                    out[key] = jax.tree_util.tree_map(rep, sub)
            segs.append(out)
        return {"segments": segs, "len": P(), "page_table": P()}

    def _alloc_row(self, row: int) -> np.ndarray:
        if not self._row_pages[row]:
            if len(self._free) < self.pages_per_row:
                raise RuntimeError(
                    f"paged KV pool exhausted: row {row} needs "
                    f"{self.pages_per_row} pages, {len(self._free)} free "
                    "(gate admission with can_admit())")
            self._row_pages[row] = [self._free.pop()
                                    for _ in range(self.pages_per_row)]
        return np.asarray(self._row_pages[row], np.int32)

    def _scatter_entry(self, pool_entry, dense_entry, slots):
        """Write a dense cache entry's first ``len(slots)`` logical slots
        into the pool. pool leaves: (reps, NP, ps, ...); dense leaves:
        (reps, B?, S, ...) pre-indexed to match ``slots``'s batch shape."""
        def one(pool, src):
            flat = pool.reshape((pool.shape[0], pool.shape[1] * pool.shape[2])
                                + pool.shape[3:])
            flat = flat.at[:, slots].set(src.astype(pool.dtype))
            return flat.reshape(pool.shape)
        return jax.tree_util.tree_map(one, pool_entry, dense_entry)

    def from_prefill(self, dense_cache: Any) -> Any:
        B = self.batch
        table_np = np.stack([self._alloc_row(r) for r in range(B)])
        table = jnp.asarray(table_np)
        cache = self.empty_cache()
        segs = [dict(e) for e in cache["segments"]]
        for seg, key, is_attn in self._attention_units():
            dense_entry = dense_cache["segments"][seg][key]
            if not is_attn:
                segs[seg][key] = dense_entry      # per-row state: unchanged
                continue
            S = jax.tree_util.tree_leaves(dense_entry)[0].shape[2]
            slots = paged_lib.view_slots(table, self.page_size)[:, :S]  # (B,S)
            segs[seg][key] = self._scatter_entry(
                cache["segments"][seg][key], dense_entry, slots)
        return {"segments": segs, "len": dense_cache["len"],
                "page_table": table}

    def insert_row(self, cache: Any, row: int, row_cache: Any) -> Any:
        pages = self._alloc_row(row)
        table = cache["page_table"].at[row].set(jnp.asarray(pages))
        row_slots = (pages[:, None] * self.page_size
                     + np.arange(self.page_size)[None, :]).reshape(-1)
        segs = [dict(e) for e in cache["segments"]]
        for seg, key, is_attn in self._attention_units():
            src = row_cache["segments"][seg][key]
            if not is_attn:
                segs[seg][key] = insert_row_pytree(
                    cache["segments"][seg][key], src, row, self.batch)
                continue
            S = jax.tree_util.tree_leaves(src)[0].shape[2]
            slots = jnp.asarray(row_slots[:S])                   # (S,)
            src_rows = jax.tree_util.tree_map(lambda x: x[:, 0], src)
            segs[seg][key] = self._scatter_entry(
                cache["segments"][seg][key], src_rows, slots)
        length = cache["len"].at[row].set(row_cache["len"][0])
        return {"segments": segs, "len": length, "page_table": table}

    def retire_row(self, cache: Any, row: int) -> Any:
        self._free.extend(self._row_pages[row])
        self._row_pages[row] = []
        table = cache["page_table"].at[row].set(self.trash_page)
        return dict(cache, len=cache["len"].at[row].set(0),
                    page_table=table)


def make_cache_manager(model, batch: int, seq_len: int,
                       spec: Union[None, str, CacheSpec]) -> KVCacheManager:
    spec = CacheSpec.resolve(spec, model.run.serve)
    cls = PagedKVCache if spec.kind == "paged" else DenseKVCache
    return cls(model, batch, seq_len, spec)
