"""AdamW with decoupled weight decay and global-norm clipping (pure JAX).

Optimizer state is a pytree congruent with params, so GSPMD shards it exactly
like the (FSDP-sharded) parameters — ZeRO for free.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(cfg: TrainConfig, params, grads, state: AdamWState,
                 lr: jnp.ndarray) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, state.m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g,
                               state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), {"grad_norm": gnorm}
