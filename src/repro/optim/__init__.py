from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import make_schedule
