"""LR schedules: cosine, constant, and WSD (Warmup-Stable-Decay — MiniCPM's
schedule, arXiv:2404.06395 §4: warmup → long stable plateau → short decay)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.config import TrainConfig


def make_schedule(cfg: TrainConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    peak = cfg.learning_rate
    warm = max(cfg.warmup_steps, 1)
    total = max(cfg.steps, warm + 1)

    if cfg.schedule == "constant":
        def sched(step):
            s = jnp.asarray(step, jnp.float32)
            return peak * jnp.minimum(1.0, s / warm)
    elif cfg.schedule == "wsd":
        decay_start = int(total * 0.9)  # MiniCPM: final ~10% decays

        def sched(step):
            s = jnp.asarray(step, jnp.float32)
            warmup = jnp.minimum(1.0, s / warm)
            frac = jnp.clip((s - decay_start) / max(total - decay_start, 1),
                            0.0, 1.0)
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
            return peak * warmup * decay
    else:  # cosine
        def sched(step):
            s = jnp.asarray(step, jnp.float32)
            warmup = jnp.minimum(1.0, s / warm)
            frac = jnp.clip((s - warm) / max(total - warm, 1), 0.0, 1.0)
            decay = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
            return peak * warmup * decay
    return sched
