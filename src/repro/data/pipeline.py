"""Deterministic, resumable synthetic data pipeline.

Design mirrors a production grain/tf.data stack at the interface level:
  * ``DataPipeline(cfg, model_cfg)`` is an iterator of batches keyed ONLY by
    (seed, step) — a counter-based (stateless-random) pipeline, so restoring
    ``state_dict()`` after preemption reproduces the exact token stream with
    no file offsets to replay (the fault-tolerance story: checkpoint carries
    {"data_step": N} and the pipeline resumes bit-identically).
  * batches are host-local numpy; the launcher shards them over the mesh's
    data axis with ``jax.make_array_from_process_local_data`` in multi-host
    deployments (single-host путь: device_put with a NamedSharding).

Synthetic text: a mixture of Zipf-distributed unigrams and short repeated
motifs so the LM loss has learnable structure (tests assert loss decreases).
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.models import frontends


def make_batch_specs(model_cfg: ModelConfig, batch: int, seq: int
                     ) -> Dict[str, Any]:
    """Shape/dtype spec of one batch (consumed by dryrun input_specs)."""
    if model_cfg.frontend == "audio_frames":
        return {
            "frames": ((batch, seq, model_cfg.d_model), np.float32),
            "targets": ((batch, seq), np.int32),
            "mask": ((batch, seq), np.bool_),
        }
    spec: Dict[str, Any] = {"tokens": ((batch, seq), np.int32)}
    if model_cfg.frontend == "vision_patches":
        spec["patches"] = ((batch, model_cfg.frontend_tokens,
                            frontends.FRONTEND_DIM), np.float32)
    return spec


class DataPipeline:
    def __init__(self, model_cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0):
        self.model_cfg = model_cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = start_step
        # Zipf over a scaled-down effective vocab keeps smoke losses learnable
        self._vocab = model_cfg.vocab_size

    # ----- persistence -----
    def state_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "data_step": self.step}

    @classmethod
    def from_state(cls, model_cfg: ModelConfig, batch: int, seq: int,
                   state: Dict[str, int]) -> "DataPipeline":
        return cls(model_cfg, batch, seq, seed=state["seed"],
                   start_step=state["data_step"])

    # ----- generation -----
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        v = self._vocab
        z = rng.zipf(1.3, size=shape).astype(np.int64)
        base = (z - 1) % v
        # inject repeated motifs: with p=.5 copy the previous 8-token window
        out = base.reshape(shape)
        B, S = shape
        for b in range(B):
            if rng.random() < 0.5 and S >= 17:
                start = int(rng.integers(8, S - 8))
                out[b, start:start + 8] = out[b, start - 8:start]
        return out.astype(np.int32)

    def next(self) -> Dict[str, np.ndarray]:
        rng = self._rng(self.step)
        self.step += 1
        cfg = self.model_cfg
        if cfg.frontend == "audio_frames":
            frames = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model)).astype(np.float32)
            targets = self._tokens(rng, (self.batch, self.seq)) % cfg.vocab_size
            mask = rng.random((self.batch, self.seq)) < 0.3
            return {"frames": frames, "targets": targets, "mask": mask}
        batch: Dict[str, np.ndarray] = {
            "tokens": self._tokens(rng, (self.batch, self.seq))}
        if cfg.frontend == "vision_patches":
            batch["patches"] = rng.standard_normal(
                (self.batch, cfg.frontend_tokens, frontends.FRONTEND_DIM)
            ).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
