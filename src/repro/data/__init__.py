from repro.data.pipeline import DataPipeline, make_batch_specs
