from repro.train.loop import TrainLoop, make_train_step
