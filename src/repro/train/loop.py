"""Training loop: step builder (grad accumulation, remat via ModelFlags,
schedule) + fault-tolerant host loop (checkpoint/restart, stragglers,
preemption)."""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import RunConfig, TrainConfig
from repro.data import DataPipeline
from repro.models.model import Model
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.optim.adamw import AdamWState
from repro.runtime.fault import PreemptionGuard, StragglerMonitor


def make_train_step(model: Model, cfg: TrainConfig, param_pspec=None
                    ) -> Callable[[Any, AdamWState, Dict[str, jnp.ndarray]],
                                  Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]]:
    """Builds the (jit-able) train step. Supports gradient accumulation over
    ``cfg.microbatch``-sized chunks via ``lax.scan`` (memory-bounded) when
    microbatch > 0.

    param_pspec: optional PartitionSpec pytree congruent with params — pins
    the gradient-accumulator scan carry to the parameter sharding (otherwise
    GSPMD materializes FULL fp32 weight gradients inside the loop: 1.5 GB per
    matrix on command-r-plus)."""
    sched = make_schedule(cfg)

    def _pin(tree):
        if param_pspec is None:
            return tree
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            tree, param_pspec)

    def loss_fn(params, batch):
        loss, aux = model.train_loss(params, batch)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if cfg.microbatch and cfg.microbatch > 0:
            some = jax.tree_util.tree_leaves(batch)[0]
            B = some.shape[0]
            mb = cfg.microbatch
            assert B % mb == 0, f"batch {B} % microbatch {mb}"
            nm = B // mb
            batch_r = jax.tree_util.tree_map(
                lambda x: x.reshape((nm, mb) + x.shape[1:]), batch)

            def acc(carry, chunk):
                gsum, lsum = carry
                (loss, aux), g = grad_fn(params, chunk)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (_pin(gsum), lsum + loss), None

            zero = _pin(jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(acc, (zero, jnp.float32(0.0)),
                                           batch_r)
            grads = jax.tree_util.tree_map(lambda g: g / nm, gsum)
            loss = lsum / nm
        else:
            (loss, aux), grads = grad_fn(params, batch)
        lr = sched(opt_state.step)
        params, opt_state, stats = adamw_update(cfg, params, grads, opt_state,
                                                lr)
        stats = dict(stats, loss=loss, lr=lr)
        return params, opt_state, stats

    return train_step


class TrainLoop:
    """Host-side loop: data, jit'd step, checkpoints, fault handling."""

    def __init__(self, model: Model, run: RunConfig, params,
                 ckpt_dir: Optional[str] = None, host_id: int = 0):
        self.model = model
        self.run = run
        self.cfg = run.train
        self.params = params
        self.opt_state = adamw_init(params)
        self.step_fn = jax.jit(make_train_step(model, self.cfg))
        self.pipeline = DataPipeline(model.cfg, self.cfg.global_batch,
                                     self.cfg.seq_len, seed=self.cfg.seed)
        self.ckpt = (CheckpointManager(ckpt_dir, keep=self.cfg.keep_checkpoints)
                     if ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.guard = PreemptionGuard()
        self.host_id = host_id
        self.step = 0
        self.history: list = []

    # ----- fault tolerance -----
    def try_restore(self) -> bool:
        if self.ckpt is None:
            return False
        out = self.ckpt.restore_latest(
            {"params": self.params, "opt": self.opt_state})
        if out is None:
            return False
        step, tree, extra = out
        self.params = tree["params"]
        self.opt_state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(self.opt_state),
            jax.tree_util.tree_leaves(tree["opt"]))
        self.step = step
        self.pipeline = DataPipeline.from_state(
            self.model.cfg, self.cfg.global_batch, self.cfg.seq_len,
            extra["data"])
        return True

    def save(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"data": self.pipeline.state_dict()})

    # ----- main loop -----
    def run_steps(self, n: Optional[int] = None) -> Dict[str, float]:
        n = n if n is not None else self.cfg.steps
        last = {}
        for _ in range(n):
            batch = {k: jnp.asarray(v) for k, v in self.pipeline.next().items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, stats = self.step_fn(
                self.params, self.opt_state, batch)
            stats = {k: float(v) for k, v in stats.items()}
            dt = time.perf_counter() - t0
            self.monitor.record(self.host_id, dt)
            self.step += 1
            stats["step_time"] = dt
            self.history.append(stats)
            last = stats
            if self.ckpt and self.step % self.cfg.checkpoint_every == 0:
                self.save()
            if self.guard.should_save():
                self.save()
                if self.ckpt:
                    self.ckpt.wait()
                break
        if self.ckpt:
            self.ckpt.wait()
        return last
