"""Pallas TPU kernels for SpecEE's compute hot spots.

Each kernel package ships three files:
  <name>.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (auto-selects interpret mode off-TPU)
  ref.py    — pure-jnp oracle used by the allclose tests

Kernels:
  spec_head        — the paper's custom operator (§6.2), TPU-adapted: fused
                     gather of LM-head columns for the speculative ids +
                     per-row (1×D)·(D×k) MXU matmul. Replaces the CUDA
                     cutlass/MegaBlocks group-GEMM with one dense row-batched
                     kernel (tree nodes = rows).
  predictor_mlp    — fused 2-layer MLP predictor (T1), one HBM round-trip.
  exit_gate        — the fused exit-gate pipeline: spec-head features +
                     predictor MLP in one kernel, plus the streaming LM-head
                     argmax-verify kernel (never materializes (B, V) logits).
  flash_attention  — blocked causal/windowed flash attention (prefill path).
  decode_attention — split-KV (flash-decoding) attention for 32k/500k decode.
"""


def on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Pallas interpret mode: True off-TPU (CPU CI), False on real hardware."""
    return not on_tpu()


def tpu_compiler_params(**kwargs):
    """Version-portable ``pltpu.CompilerParams`` (named ``TPUCompilerParams``
    on jax<=0.4.x). Every kernel's ``compiler_params=`` goes through here."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
