"""Pure-jnp oracle for the fused 2-layer predictor MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def predictor_mlp_ref(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                      w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """x: (B, F); w1: (F, H); b1: (H,); w2: (H, 1); b2: (1,) -> (B,) prob."""
    h = jax.nn.relu(x.astype(jnp.float32) @ w1.astype(jnp.float32)
                    + b1.astype(jnp.float32))
    out = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return jax.nn.sigmoid(out[..., 0])
