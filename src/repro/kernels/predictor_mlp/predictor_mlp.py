"""Pallas TPU kernel: fused 2-layer predictor MLP (T1, paper §4.3.2).

The predictor is tiny ((12→512→1) ≈ 13 KB of weights) and memory-bound
(paper §7.3.1) — the win on TPU is doing GEMM→ReLU→GEMV→sigmoid in ONE kernel
so features make a single HBM→VMEM trip and intermediates never spill.

Whole weight matrices fit VMEM trivially; the grid tiles only the row (batch)
dimension. Feature dim F (=12) and the output column are padded to the
128-lane boundary by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                      # (Bt, F)
    w1 = w1_ref[...].astype(jnp.float32)                    # (F, H)
    b1 = b1_ref[...].astype(jnp.float32)                    # (1, H)
    w2 = w2_ref[...].astype(jnp.float32)                    # (H, 1)
    b2 = b2_ref[...].astype(jnp.float32)                    # (1, 1)
    h = jnp.maximum(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1,
                    0.0)
    out = jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2
    out_ref[...] = jax.nn.sigmoid(out)                      # (Bt, 1)


def predictor_mlp_fused(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                        w2: jnp.ndarray, b2: jnp.ndarray,
                        block_b: int = 256) -> jnp.ndarray:
    """x: (B, F) -> (B,) exit probabilities."""
    B, F = x.shape
    H = w1.shape[1]
    block_b = min(block_b, B)
    # pad rows to a multiple of the block
    pad_b = (-B) % block_b
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    nb = x.shape[0] // block_b

    from repro.kernels import interpret_default
    fn = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
            pl.BlockSpec((H, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        interpret=interpret_default(),
        name="specee_predictor_mlp",
    )
    out = fn(x, w1, b1.reshape(1, H), w2, b2.reshape(1, 1))
    return out[:B, 0]


# ---------------------------------------------------------------------------
# quantized weights: int8 / packed-int4 codes + per-column scales
# ---------------------------------------------------------------------------
def _deq(q_ref, s_ref, x, bits):
    """x @ dequant(q): fold the per-output-column scale after the dot.

    int4 codes are plane-packed (repro.quant): the byte matrix holds row i
    in the low nibble and row i + d_in/2 in the high nibble, so the two
    planes contract against the static halves of ``x`` — no interleave.
    """
    s = s_ref[...].astype(jnp.float32)                       # (1, d_out)
    if bits == 4:
        p = q_ref[...].astype(jnp.int32)                     # (d_in/2, d_out)
        lo = ((p << 28) >> 28).astype(jnp.float32)
        hi = (p >> 4).astype(jnp.float32)
        half = p.shape[0]
        part = (jnp.dot(x[:, :half], lo, preferred_element_type=jnp.float32)
                + jnp.dot(x[:, half:], hi,
                          preferred_element_type=jnp.float32))
    else:
        part = jnp.dot(x, q_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    return part * s


def _kernel_q(x_ref, q1_ref, s1_ref, b1_ref, q2_ref, s2_ref, b2_ref,
              out_ref, *, bits1: int, bits2: int):
    x = x_ref[...].astype(jnp.float32)                       # (Bt, F)
    h = jnp.maximum(_deq(q1_ref, s1_ref, x, bits1)
                    + b1_ref[...].astype(jnp.float32), 0.0)  # (Bt, H)
    out = _deq(q2_ref, s2_ref, h, bits2) + b2_ref[...].astype(jnp.float32)
    out_ref[...] = jax.nn.sigmoid(out)                       # (Bt, 1)


def predictor_mlp_fused_q(x: jnp.ndarray, qw1, b1: jnp.ndarray, qw2,
                          b2: jnp.ndarray, block_b: int = 256) -> jnp.ndarray:
    """Quantized-bank sibling of ``predictor_mlp_fused``: qw1/qw2 are
    ``repro.quant.QTensor`` weights ((F, H) and (H, 1) logical shapes);
    codes + scales make the single HBM→VMEM trip and the fp weights never
    exist. x: (B, F) -> (B,) exit probabilities.
    """
    B, F = x.shape
    H = qw1.shape[-1]
    block_b = min(block_b, B)
    pad_b = (-B) % block_b
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    nb = x.shape[0] // block_b
    r1, r2 = qw1.q.shape[0], qw2.q.shape[0]   # packed row counts
    s1 = qw1.scale.reshape(1, H)
    s2 = qw2.scale.reshape(1, 1)

    from repro.kernels import interpret_default
    fn = pl.pallas_call(
        functools.partial(_kernel_q, bits1=qw1.bits, bits2=qw2.bits),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((r1, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
            pl.BlockSpec((r2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        interpret=interpret_default(),
        name=f"specee_predictor_mlp_q{qw1.bits}",
    )
    out = fn(x, qw1.q, s1, b1.reshape(1, H), qw2.q, s2, b2.reshape(1, 1))
    return out[:B, 0]
