"""Pallas TPU kernel: fused 2-layer predictor MLP (T1, paper §4.3.2).

The predictor is tiny ((12→512→1) ≈ 13 KB of weights) and memory-bound
(paper §7.3.1) — the win on TPU is doing GEMM→ReLU→GEMV→sigmoid in ONE kernel
so features make a single HBM→VMEM trip and intermediates never spill.

Whole weight matrices fit VMEM trivially; the grid tiles only the row (batch)
dimension. Feature dim F (=12) and the output column are padded to the
128-lane boundary by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                      # (Bt, F)
    w1 = w1_ref[...].astype(jnp.float32)                    # (F, H)
    b1 = b1_ref[...].astype(jnp.float32)                    # (1, H)
    w2 = w2_ref[...].astype(jnp.float32)                    # (H, 1)
    b2 = b2_ref[...].astype(jnp.float32)                    # (1, 1)
    h = jnp.maximum(jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1,
                    0.0)
    out = jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2
    out_ref[...] = jax.nn.sigmoid(out)                      # (Bt, 1)


def predictor_mlp_fused(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                        w2: jnp.ndarray, b2: jnp.ndarray,
                        block_b: int = 256) -> jnp.ndarray:
    """x: (B, F) -> (B,) exit probabilities."""
    B, F = x.shape
    H = w1.shape[1]
    block_b = min(block_b, B)
    # pad rows to a multiple of the block
    pad_b = (-B) % block_b
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    nb = x.shape[0] // block_b

    from repro.kernels import interpret_default
    fn = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((1, H), lambda i: (0, 0)),
            pl.BlockSpec((H, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        interpret=interpret_default(),
        name="specee_predictor_mlp",
    )
    out = fn(x, w1, b1.reshape(1, H), w2, b2.reshape(1, 1))
    return out[:B, 0]
