"""Public jit'd wrapper for the fused predictor MLP."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.predictor_mlp.predictor_mlp import (predictor_mlp_fused,
                                                       predictor_mlp_fused_q)
from repro.quant import QTensor


def _run(x: jnp.ndarray, p) -> jnp.ndarray:
    l1, l2 = p["layers"]
    if isinstance(l1["w"], QTensor):
        return predictor_mlp_fused_q(x, l1["w"], l1["b"], l2["w"], l2["b"])
    return predictor_mlp_fused(x, l1["w"], l1["b"], l2["w"], l2["b"])


@jax.jit
def predictor_mlp(x: jnp.ndarray, params) -> jnp.ndarray:
    """x: (B, F); params: {"layers": [{w,b}, {w,b}]} (repro.core.predictor
    layout, 2-layer case; ``w`` leaves may be ``repro.quant.QTensor`` —
    dequant then fuses into the kernel tiles) -> (B,) exit probabilities."""
    return _run(x, params)


@jax.jit
def predictor_mlp_at(x: jnp.ndarray, stacked, ep: jnp.ndarray) -> jnp.ndarray:
    """Stacked-bank entry: dynamic-index predictor ``ep`` out of the
    (E, ...)-stacked bank and run the fused MLP, all inside one jit so the
    weight slice feeds the kernel without an HBM round-trip. Quantized
    banks (QTensor ``w`` leaves) index transparently — codes and scales
    both carry the leading (E,) dim.

    x: (B, F); stacked: bank with leading (E,) on every leaf."""
    p = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, ep, 0, False), stacked)
    return _run(x, p)
