"""Public jit'd wrapper for the fused predictor MLP."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.predictor_mlp.predictor_mlp import predictor_mlp_fused


@jax.jit
def predictor_mlp(x: jnp.ndarray, params) -> jnp.ndarray:
    """x: (B, F); params: {"layers": [{w,b}, {w,b}]} (repro.core.predictor
    layout, 2-layer case) -> (B,) exit probabilities."""
    l1, l2 = params["layers"]
    return predictor_mlp_fused(x, l1["w"], l1["b"], l2["w"], l2["b"])
