"""Pallas TPU kernel: fused speculative-LM-head (paper §6.2, TPU-adapted).

The paper computes speculative token logits with a cutlass/MegaBlocks group
GEMM over LM-head *columns* selected by the draft's token ids. On TPU we
instead drive the column gather from **scalar-prefetched indices in the
BlockSpec index_map**: grid cell (b, j, d) streams block d of LM-head column
``spec_ids[b, j]`` into VMEM and accumulates the (1×Dt)·(Dt×1) partial dot
into the (b, j) output element. HBM traffic is exactly k columns per row
(k·D·4 bytes) instead of the V·D bytes a full-head matmul would read — the
10⁴× search-space reduction made physical.

Grid: (B, k, D/Dt), Dt = 128-aligned reduction tile. The reduction dimension
is innermost ("arbitrary" semantics) so the fp32 accumulation in the output
block is legal on TPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, h_ref, w_ref, out_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[...].astype(jnp.float32)        # (1, Dt)
    w = w_ref[...].astype(jnp.float32)        # (Dt, 1)
    out_ref[...] += jnp.dot(h, w, preferred_element_type=jnp.float32)


def spec_head_logits(hn: jnp.ndarray, lm_head: jnp.ndarray,
                     spec_ids: jnp.ndarray, block_d: int = 512
                     ) -> jnp.ndarray:
    """hn: (B, D); lm_head: (D, V); spec_ids: (B, k) -> logits (B, k) fp32."""
    B, D = hn.shape
    _, V = lm_head.shape
    k = spec_ids.shape[1]
    block_d = min(block_d, D)
    while D % block_d:
        block_d //= 2
    nd = D // block_d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, k, nd),
        in_specs=[
            # h row b, reduction tile d
            pl.BlockSpec((1, block_d), lambda b, j, d, ids: (b, d)),
            # LM-head column spec_ids[b, j], reduction tile d
            pl.BlockSpec((block_d, 1), lambda b, j, d, ids: (d, ids[b, j])),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, j, d, ids: (b, j)),
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(),
        name="specee_spec_head",
    )
    return fn(spec_ids, hn, lm_head)


# ---------------------------------------------------------------------------
# quantized LM head: int8 / packed-int4 column gather, dequant in-register
# ---------------------------------------------------------------------------
def _kernel_q8(ids_ref, h_ref, w_ref, s_ref, out_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[...].astype(jnp.float32)        # (1, Dt)
    w = w_ref[...].astype(jnp.float32)        # (Dt, 1) int8 codes
    out_ref[...] += (jnp.dot(h, w, preferred_element_type=jnp.float32)
                     * s_ref[0, 0])


def _kernel_q4(ids_ref, hlo_ref, hhi_ref, w_ref, s_ref, out_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h_lo = hlo_ref[...].astype(jnp.float32)   # (1, Dt) rows [0, D/2)
    h_hi = hhi_ref[...].astype(jnp.float32)   # (1, Dt) rows [D/2, D)
    p = w_ref[...].astype(jnp.int32)          # (Dt, 1) packed bytes
    lo = ((p << 28) >> 28).astype(jnp.float32)
    hi = (p >> 4).astype(jnp.float32)
    part = (jnp.dot(h_lo, lo, preferred_element_type=jnp.float32)
            + jnp.dot(h_hi, hi, preferred_element_type=jnp.float32))
    out_ref[...] += part * s_ref[0, 0]


def spec_head_logits_q(hn: jnp.ndarray, qt, spec_ids: jnp.ndarray,
                       block_d: int = 512) -> jnp.ndarray:
    """Quantized-head sibling of ``spec_head_logits``. qt: QTensor of
    logical shape (D, V) (int8 codes or the plane-packed int4 layout from
    ``repro.quant``). The scalar-prefetched gather streams k integer
    columns + k scale scalars per row; dequant is the per-tile
    scale multiply, so the result matches the dequantized reference
    exactly (per-column scales: dequant∘gather ≡ gather∘dequant).
    """
    B, D = hn.shape
    k = spec_ids.shape[1]
    q = qt.q
    V = q.shape[-1]
    scale = qt.scale.reshape(1, V)
    rows = q.shape[0]                          # D (int8) or D/2 (int4)
    block_d = min(block_d, rows)
    while rows % block_d:
        block_d //= 2
    nd = rows // block_d

    w_spec = pl.BlockSpec((block_d, 1), lambda b, j, d, ids: (d, ids[b, j]))
    s_spec = pl.BlockSpec((1, 1), lambda b, j, d, ids: (0, ids[b, j]))
    if qt.bits == 4:
        in_specs = [
            pl.BlockSpec((1, block_d), lambda b, j, d, ids: (b, d)),
            pl.BlockSpec((1, block_d),
                         lambda b, j, d, ids, nd=nd: (b, d + nd)),
            w_spec, s_spec,
        ]
        operands = (spec_ids, hn, hn, q, scale)
        kernel = _kernel_q4
    else:
        in_specs = [pl.BlockSpec((1, block_d), lambda b, j, d, ids: (b, d)),
                    w_spec, s_spec]
        operands = (spec_ids, hn, q, scale)
        kernel = _kernel_q8

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, k, nd),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda b, j, d, ids: (b, j)),
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(),
        name=f"specee_spec_head_q{qt.bits}",
    )
    return fn(*operands)
