"""Pallas TPU kernel: fused speculative-LM-head (paper §6.2, TPU-adapted).

The paper computes speculative token logits with a cutlass/MegaBlocks group
GEMM over LM-head *columns* selected by the draft's token ids. On TPU we
instead drive the column gather from **scalar-prefetched indices in the
BlockSpec index_map**: grid cell (b, j, d) streams block d of LM-head column
``spec_ids[b, j]`` into VMEM and accumulates the (1×Dt)·(Dt×1) partial dot
into the (b, j) output element. HBM traffic is exactly k columns per row
(k·D·4 bytes) instead of the V·D bytes a full-head matmul would read — the
10⁴× search-space reduction made physical.

Grid: (B, k, D/Dt), Dt = 128-aligned reduction tile. The reduction dimension
is innermost ("arbitrary" semantics) so the fp32 accumulation in the output
block is legal on TPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, h_ref, w_ref, out_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[...].astype(jnp.float32)        # (1, Dt)
    w = w_ref[...].astype(jnp.float32)        # (Dt, 1)
    out_ref[...] += jnp.dot(h, w, preferred_element_type=jnp.float32)


def spec_head_logits(hn: jnp.ndarray, lm_head: jnp.ndarray,
                     spec_ids: jnp.ndarray, block_d: int = 512
                     ) -> jnp.ndarray:
    """hn: (B, D); lm_head: (D, V); spec_ids: (B, k) -> logits (B, k) fp32."""
    B, D = hn.shape
    _, V = lm_head.shape
    k = spec_ids.shape[1]
    block_d = min(block_d, D)
    while D % block_d:
        block_d //= 2
    nd = D // block_d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, k, nd),
        in_specs=[
            # h row b, reduction tile d
            pl.BlockSpec((1, block_d), lambda b, j, d, ids: (b, d)),
            # LM-head column spec_ids[b, j], reduction tile d
            pl.BlockSpec((block_d, 1), lambda b, j, d, ids: (d, ids[b, j])),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, j, d, ids: (b, j)),
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(),
        name="specee_spec_head",
    )
    return fn(spec_ids, hn, lm_head)
