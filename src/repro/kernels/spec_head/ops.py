"""Public jit'd wrapper for the fused speculative LM head."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.spec_head.spec_head import (spec_head_logits,
                                               spec_head_logits_q)
from repro.quant import QTensor


@partial(jax.jit, static_argnames=("block_d",))
def spec_head(hn: jnp.ndarray, lm_head, spec_ids: jnp.ndarray,
              block_d: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused gather + k-GEMM + softmax.

    hn: (B, D) final-normed hidden; lm_head: (D, V) array or a
    ``repro.quant.QTensor`` (int8 / packed-int4 codes + per-column scales
    — dequant fuses into the gather tiles); spec_ids: (B, k) int32.
    Returns (logits (B, k) fp32, local_probs (B, k) fp32).
    """
    if isinstance(lm_head, QTensor):
        logits = spec_head_logits_q(hn, lm_head, spec_ids, block_d=block_d)
    else:
        logits = spec_head_logits(hn, lm_head, spec_ids, block_d=block_d)
    return logits, jax.nn.softmax(logits, axis=-1)
