"""Pure-jnp oracle for the speculative LM head (gather + k-GEMM + softmax)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def spec_head_ref(hn: jnp.ndarray, lm_head,
                  spec_ids: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hn: (B, D); lm_head: (D, V) array or ``repro.quant.QTensor``;
    spec_ids: (B, k) int32.

    A quantized head is gathered-then-dequantized — bit-identical to
    dequantize-then-gather because the scales are per-column.
    Returns (logits (B, k) fp32, local_probs (B, k) fp32).
    """
    from repro.quant import QTensor, take_columns
    if isinstance(lm_head, QTensor):
        cols = take_columns(lm_head, spec_ids)        # (D, B, k) fp32
    else:
        cols = jnp.take(lm_head, spec_ids, axis=1)    # (D, B, k)
    cols = jnp.moveaxis(cols, 1, 0)                   # (B, D, k)
    logits = jnp.einsum("bd,bdk->bk", hn.astype(jnp.float32),
                        cols.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    return logits, probs
