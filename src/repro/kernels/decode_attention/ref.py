"""Pure-jnp oracle for split-KV decode attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len,
                         window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, 1, H, hd); k_cache/v_cache: (B, S, KVH, hd);
    cache_len: scalar or (B,) int32. Returns (B, 1, H, hd)."""
    B, S, KVH, hd = k_cache.shape
    H = q.shape[2]
    n_rep = H // KVH
    if n_rep > 1:
        k_cache = jnp.repeat(k_cache, n_rep, axis=2)
        v_cache = jnp.repeat(v_cache, n_rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32)
    logits = logits * scale
    kpos = jnp.arange(S)[None, :]
    clen = jnp.reshape(cache_len, (-1, 1))
    valid = kpos < clen
    if window is not None:
        valid = valid & (kpos >= clen - window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v_cache.dtype), v_cache)


def paged_decode_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, page_table: jnp.ndarray,
                               cache_len,
                               window: Optional[int] = None) -> jnp.ndarray:
    """Oracle for the paged kernel: gather the logical view, then run the
    dense reference. k_pool/v_pool: (n_pages, ps, KVH, hd);
    page_table: (B, P) int32."""
    from repro.core import paged as paged_lib
    k_cache = paged_lib.gather_view(k_pool, page_table)
    v_cache = paged_lib.gather_view(v_pool, page_table)
    return decode_attention_ref(q, k_cache, v_cache, cache_len, window=window)
