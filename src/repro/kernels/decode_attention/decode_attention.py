"""Pallas TPU kernel: split-KV (flash-decoding) attention for long-context
decode (decode_32k / long_500k shapes).

One query token vs. a (B, S, KVH, hd) cache. The sequence dimension is tiled
(grid dim innermost, "arbitrary") with online-softmax scratch carried across
tiles — so a 512k-token cache streams through VMEM in ``block_k`` chunks and
the HBM traffic is exactly one pass over the valid prefix. ``cache_len`` is
scalar-prefetched: tiles entirely beyond the valid prefix (or entirely below
the sliding window) are skipped with ``pl.when`` — decode cost is
proportional to the *live* context, not the allocated cache.

The q head group for a KV head is processed as the matrix row dimension
(GQA-natural layout): q block (n_rep, hd) × k block (hd, Bk) uses the MXU
even at decode (n_rep up to 16 for our archs — paired with 128-wide k tiles).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _online_softmax_step(ki, clen, k_start, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         block_k: int, window: Optional[int], nk: int,
                         ks_ref=None, vs_ref=None):
    """Shared flash-decoding tile body for the dense and paged kernels.

    The two kernels differ ONLY in how a grid step locates its K/V block
    (sequential block index vs page-table indirection) — every numerics
    decision (masking, NEG_INF, online-softmax accumulation, the l == 0
    guard for fully-masked rows) lives here exactly once. ``k_start`` is the
    LOGICAL position of the block's first key.

    ``ks_ref``/``vs_ref``: optional per-(position, head) int8 dequant scales
    (``ModelFlags.kv_quant`` pools) as (Bk, 1) tiles; when present the K/V
    tiles are int8 codes and dequant happens here, in-register — the same
    per-position scales ``model._kv_dequantize`` applies to the gathered
    view, so dequant∘gather ≡ gather∘dequant holds bit-for-bit.
    """
    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = k_start < clen
    if window is not None:
        live = live & (k_start + block_k > clen - window)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                 # (n_rep, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (Bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                 # (Bk, hd)
        if ks_ref is not None:
            k = k * ks_ref[0, 0].astype(jnp.float32)        # (Bk, 1) scales
        if vs_ref is not None:
            v = v * vs_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < clen
        if window is not None:
            valid = valid & (kpos >= clen - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    pl.when(live)(_body)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int, window: Optional[int], nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    _online_softmax_step(ki, len_ref[b], ki * block_k, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, scale=scale,
                         block_k=block_k, window=window, nk=nk)


def decode_attention_fwd(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len,
                         window: Optional[int] = None,
                         block_k: int = 512) -> jnp.ndarray:
    """q: (B, 1, H, hd); k_cache/v_cache: (B, S, KVH, hd);
    cache_len: scalar or (B,). Returns (B, 1, H, hd)."""
    B, S, KVH, hd = k_cache.shape
    H = q.shape[2]
    n_rep = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    while S % block_k:
        block_k //= 2
    nk = S // block_k

    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    # (B, KVH, n_rep, hd) — q head h belongs to kv group h // n_rep, so the
    # head group becomes the q-block row dimension
    qg = q[:, 0].reshape(B, KVH, n_rep, hd)
    kt = jnp.moveaxis(k_cache, 2, 1)                         # (B,KVH,S,hd)
    vt = jnp.moveaxis(v_cache, 2, 1)

    from repro.kernels import interpret_default, tpu_compiler_params
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k,
                               window=window, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, n_rep, hd),
                         lambda b, g, ki, lens: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, g, ki, lens: (b, g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, g, ki, lens: (b, g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, n_rep, hd),
                               lambda b, g, ki, lens: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, n_rep, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(),
        name="specee_decode_attention",
    )
    out = fn(clen, qg, kt, vt)                               # (B,KVH,n_rep,hd)
    out = out.reshape(B, KVH * n_rep, hd)
    return out[:, None].reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# page-table-aware variant (paged KV cache — repro.api.cache.PagedKVCache)
# ---------------------------------------------------------------------------
def _paged_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  window: Optional[int], npg: int):
    # identical tile math to the dense kernel; the page-table indirection
    # happens in the K/V BlockSpec index maps, so k_start here is the
    # LOGICAL position of page pi (pi * page_size), not the physical one
    b = pl.program_id(0)
    pi = pl.program_id(2)
    _online_softmax_step(pi, len_ref[b], pi * page_size, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, scale=scale,
                         block_k=page_size, window=window, nk=npg)


def _paged_kernel_q(len_ref, tbl_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                    o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                    page_size: int, window: Optional[int], npg: int):
    # int8 pools (ModelFlags.kv_quant): same tile math, but the K/V pages
    # arrive as int8 codes + per-(position, head) scale pages gathered
    # through the SAME page-table index map — dequant runs in-register
    b = pl.program_id(0)
    pi = pl.program_id(2)
    _online_softmax_step(pi, len_ref[b], pi * page_size, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, scale=scale,
                         block_k=page_size, window=window, nk=npg,
                         ks_ref=ks_ref, vs_ref=vs_ref)


def paged_decode_attention_fwd(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, page_table: jnp.ndarray,
                               cache_len, window: Optional[int] = None,
                               k_scale: Optional[jnp.ndarray] = None,
                               v_scale: Optional[jnp.ndarray] = None
                               ) -> jnp.ndarray:
    """Split-KV decode attention reading K/V through a page table.

    q: (B, 1, H, hd); k_pool/v_pool: (n_pages, page_size, KVH, hd) — the
    shared physical pool; page_table: (B, P) int32 logical→physical page map;
    cache_len: scalar or (B,) valid logical length per row.

    ``k_scale``/``v_scale``: optional (n_pages, page_size, KVH) fp32 dequant
    scale pools for int8 K/V pools (``ModelFlags.kv_quant``). Scale pages
    ride the SAME page-table index map as their value pages, so the kernel
    reads ~4× fewer K/V bytes per page and the dequantized math is
    bit-identical to dequantizing the gathered logical view (per-position
    scales commute with the gather).

    The page table is scalar-prefetched and consumed by the K/V BlockSpec
    index maps, so each grid step DMAs exactly one physical page — the
    (B, S, ...) logical view is never materialized. Pages past a row's valid
    prefix skip both compute (`pl.when`) AND traffic: their index map clamps
    to the last live page, and Pallas elides the DMA when the block index is
    unchanged between grid steps — this is what makes per-row compaction
    (freed pages, zeroed length) a real HBM-bytes win, not just masked
    compute.
    """
    n_pages, ps, KVH, hd = k_pool.shape
    B, _, H, _ = q.shape
    n_rep = H // KVH
    P = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None

    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    tbl = jnp.asarray(page_table, jnp.int32)
    qg = q[:, 0].reshape(B, KVH, n_rep, hd)
    kt = jnp.moveaxis(k_pool, 2, 1)                          # (NP,KVH,ps,hd)
    vt = jnp.moveaxis(v_pool, 2, 1)

    from repro.kernels import interpret_default, tpu_compiler_params

    def kv_page(b, g, pi, lens, tbl):
        # pages beyond the valid prefix are dead (pl.when masks compute);
        # clamp them to the last live page so consecutive grid steps keep
        # the same block index and Pallas elides the DMA entirely
        last_live = jnp.maximum((lens[b] + ps - 1) // ps - 1, 0)
        return (tbl[b, jnp.minimum(pi, last_live)], g, 0, 0)

    q_spec = pl.BlockSpec((1, 1, n_rep, hd),
                          lambda b, g, pi, lens, tbl: (b, g, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, ps, hd), kv_page)
    if quantized:
        kernel = functools.partial(_paged_kernel_q, scale=scale,
                                   page_size=ps, window=window, npg=P)
        # (NP, ps, KVH) -> (NP, KVH, ps, 1): scale pages under the value
        # pages' index map, broadcasting over hd inside the tile
        kst = jnp.moveaxis(k_scale, 2, 1).reshape(n_pages, KVH, ps, 1)
        vst = jnp.moveaxis(v_scale, 2, 1).reshape(n_pages, KVH, ps, 1)
        s_spec = pl.BlockSpec((1, 1, ps, 1), kv_page)
        in_specs = [q_spec, kv_spec, s_spec, kv_spec, s_spec]
        operands = (clen, tbl, qg, kt, kst, vt, vst)
        name = "specee_paged_decode_attention_q8"
    else:
        kernel = functools.partial(_paged_kernel, scale=scale, page_size=ps,
                                   window=window, npg=P)
        in_specs = [q_spec, kv_spec, kv_spec]
        operands = (clen, tbl, qg, kt, vt)
        name = "specee_paged_decode_attention"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, n_rep, hd),
                               lambda b, g, pi, lens, tbl: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, n_rep, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret_default(),
        name=name,
    )
    out = fn(*operands)
    out = out.reshape(B, KVH * n_rep, hd)
    return out[:, None].reshape(B, 1, H, hd)
