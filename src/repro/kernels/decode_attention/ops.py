"""Public jit'd wrapper for split-KV decode attention."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_fwd, paged_decode_attention_fwd)


@partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention_raw(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len,
                         window: Optional[int] = None,
                         block_k: int = 512) -> jnp.ndarray:
    return decode_attention_fwd(q, k_cache, v_cache, cache_len,
                                window=window, block_k=block_k)


def decode_attention(cfg, q, k_cache, v_cache, cache_len,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Model-layer adapter (matches ``attention.attend_decode`` signature)."""
    return decode_attention_raw(q, k_cache, v_cache, cache_len, window=window)


@partial(jax.jit, static_argnames=("window",))
def paged_decode_attention_raw(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray, page_table: jnp.ndarray,
                               cache_len, window: Optional[int] = None,
                               k_scale: Optional[jnp.ndarray] = None,
                               v_scale: Optional[jnp.ndarray] = None
                               ) -> jnp.ndarray:
    return paged_decode_attention_fwd(q, k_pool, v_pool, page_table,
                                      cache_len, window=window,
                                      k_scale=k_scale, v_scale=v_scale)


def paged_decode_attention(cfg, q, k_pool, v_pool, page_table, cache_len,
                           window: Optional[int] = None,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None
                           ) -> jnp.ndarray:
    """Model-layer adapter: page-table-aware gather variant consumed by the
    paged decode path (``model._block_step`` under ``flags.decode_kernel``).
    ``k_scale``/``v_scale`` carry the int8 dequant scale pools under
    ``flags.kv_quant`` (same page-table gather as the value pools)."""
    return paged_decode_attention_raw(q, k_pool, v_pool, page_table,
                                      cache_len, window=window,
                                      k_scale=k_scale, v_scale=v_scale)
