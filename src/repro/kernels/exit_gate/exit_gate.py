"""Pallas TPU kernels for the fused exit gate (see package docstring).

``exit_gate_fused`` — grid (B, k, nd), reduction tile innermost. Cell
(b, j, d) streams block d of LM-head column ``spec_ids[b, j]`` (scalar-
prefetched index_map, exactly the spec_head gather) and accumulates the
partial dot into a per-row (1, k) VMEM scratch. The LAST cell of each row
finishes the whole gate on-chip: softmax over the k logits, Δ-features
against ``prev_probs``, the 2-layer predictor GEMM→ReLU→GEMV→sigmoid —
features and intermediates never touch HBM.

``argmax_verify_fused`` — grid (B, nv, nd): vocab tiles with a D-reduction
inner loop. A (1, block_v) VMEM scratch accumulates the tile's logits; when
a tile's reduction completes, its (max, argmax) folds into SMEM running
scalars. Ties resolve to the lowest index (strict-greater update + first-max
within a tile), matching ``jnp.argmax``. HBM traffic = one pass over the LM
head; the (B, V) logits are never materialized.

``topk_verify_fused`` — the top-k sibling of the argmax kernel (draft
proposal path): same grid and tile accumulation, but each completed tile
folds into a running sorted (1, k) VMEM top-k list via k static
mask-extract-max passes over [running ∥ tile]. Because the running list is
kept in descending (value, then ascending id) order and tiles arrive in
vocab order, ties resolve to the lowest vocab index — matching
``jax.lax.top_k`` on the materialized logits exactly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fit_block(dim: int, block: int) -> int:
    block = min(block, dim)
    while dim % block:
        block //= 2
    return block


# ---------------------------------------------------------------------------
# gate: spec-head gather-GEMM + softmax + Δ-features + predictor MLP
# ---------------------------------------------------------------------------
def _gate_kernel(ids_ref, h_ref, w_ref, pp_ref, w1_ref, b1_ref, w2_ref,
                 b2_ref, p_ref, probs_ref, logits_ref, acc_ref, *,
                 k: int, nd: int):
    j = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when((j == 0) & (d == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)            # (1, Dt)
    w = w_ref[...].astype(jnp.float32)            # (Dt, 1)
    part = jnp.dot(h, w, preferred_element_type=jnp.float32)   # (1, 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)
    acc_ref[...] += jnp.where(lane == j, part[0, 0], 0.0)

    @pl.when((j == k - 1) & (d == nd - 1))
    def _finish():
        logits = acc_ref[...]                                  # (1, k)
        m = jnp.max(logits, axis=1, keepdims=True)
        e = jnp.exp(logits - m)
        probs = e / jnp.sum(e, axis=1, keepdims=True)
        delta = probs - pp_ref[...].astype(jnp.float32)
        feats = jnp.concatenate([logits, probs, delta], axis=1)  # (1, 3k)
        w1 = w1_ref[...].astype(jnp.float32)                   # (3k, H)
        hid = jnp.maximum(
            jnp.dot(feats, w1, preferred_element_type=jnp.float32)
            + b1_ref[...].astype(jnp.float32), 0.0)            # (1, H)
        out = (jnp.dot(hid, w2_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
               + b2_ref[...].astype(jnp.float32))              # (1, 1)
        p_ref[...] = jax.nn.sigmoid(out)
        probs_ref[...] = probs
        logits_ref[...] = logits


def exit_gate_fused(hn: jnp.ndarray, lm_head: jnp.ndarray,
                    spec_ids: jnp.ndarray, prev_probs: jnp.ndarray,
                    w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray,
                    b2: jnp.ndarray, block_d: int = 512
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """hn: (B, D); lm_head: (D, V); spec_ids: (B, k) int32; prev_probs:
    (B, k); predictor weights w1 (3k, H), b1 (H,), w2 (H, 1), b2 (1,).

    Returns (p_exit (B,), probs (B, k), logits (B, k)), all fp32.
    """
    B, D = hn.shape
    k = spec_ids.shape[1]
    H = w1.shape[1]
    assert w1.shape[0] == 3 * k, (w1.shape, k)
    block_d = _fit_block(D, block_d)
    nd = D // block_d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, k, nd),
        in_specs=[
            # h row b, reduction tile d
            pl.BlockSpec((1, block_d), lambda b, j, d, ids: (b, d)),
            # LM-head column spec_ids[b, j], reduction tile d
            pl.BlockSpec((block_d, 1), lambda b, j, d, ids: (d, ids[b, j])),
            # previous-layer local probs, row b
            pl.BlockSpec((1, k), lambda b, j, d, ids: (b, 0)),
            # predictor weights — whole matrices, trivially VMEM-resident
            pl.BlockSpec((3 * k, H), lambda b, j, d, ids: (0, 0)),
            pl.BlockSpec((1, H), lambda b, j, d, ids: (0, 0)),
            pl.BlockSpec((H, 1), lambda b, j, d, ids: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, j, d, ids: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j, d, ids: (b, 0)),
            pl.BlockSpec((1, k), lambda b, j, d, ids: (b, 0)),
            pl.BlockSpec((1, k), lambda b, j, d, ids: (b, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, k), jnp.float32)],
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(_gate_kernel, k=k, nd=nd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret_default(),
        name="specee_exit_gate",
    )
    p_exit, probs, logits = fn(spec_ids, hn, lm_head,
                               prev_probs.astype(jnp.float32),
                               w1, b1.reshape(1, H), w2, b2.reshape(1, 1))
    return p_exit[:, 0], probs, logits


# ---------------------------------------------------------------------------
# verify: streaming LM-head argmax (never materializes (B, V) logits)
# ---------------------------------------------------------------------------
def _verify_kernel(h_ref, w_ref, tok_ref, max_ref, acc_ref, best_ref,
                   barg_ref, *, V: int, block_v: int, nv: int, nd: int):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        best_ref[0, 0] = NEG_INF
        barg_ref[0, 0] = 0

    h = h_ref[...].astype(jnp.float32)            # (1, Dt)
    w = w_ref[...].astype(jnp.float32)            # (Dt, Vt)
    acc_ref[...] += jnp.dot(h, w, preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _fold_tile():
        col = v * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                     acc_ref.shape, 1)
        vals = jnp.where(col < V, acc_ref[...], NEG_INF)       # (1, Vt)
        tmax = jnp.max(vals)
        targ = v * block_v + jnp.argmax(vals[0, :]).astype(jnp.int32)
        better = tmax > best_ref[0, 0]
        barg_ref[0, 0] = jnp.where(better, targ, barg_ref[0, 0])
        best_ref[0, 0] = jnp.where(better, tmax, best_ref[0, 0])

        @pl.when(v == nv - 1)
        def _emit():
            tok_ref[...] = jnp.full((1, 1), barg_ref[0, 0], jnp.int32)
            max_ref[...] = jnp.full((1, 1), best_ref[0, 0], jnp.float32)


def _pick_vocab_block(V: int, block_v: int):
    """Shared no-copy block choice (see argmax_verify_fused's comment)."""
    fitted = _fit_block(V, min(block_v, V))
    if fitted >= min(128, V):
        return fitted, 0
    block_v = min(block_v, V)
    return block_v, (-V) % block_v


def argmax_verify_fused(hn: jnp.ndarray, lm_head: jnp.ndarray,
                        block_v: int = 512, block_d: int = 512
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hn: (B, D); lm_head: (D, V).

    Returns (argmax token (B,) int32, max logit (B,) fp32) with fp32
    accumulation, reading the LM head exactly once.
    """
    B, D = hn.shape
    V = lm_head.shape[1]
    block_d = _fit_block(D, block_d)
    nd = D // block_d
    # prefer a block that divides V — padding the LM head would copy the
    # whole (D, V) matrix through HBM, which is exactly the traffic this
    # kernel exists to avoid. Only pathological vocabs (e.g. minicpm's
    # odd 122753, where fitting degrades to tiny blocks) take the pad
    # path; padded columns are masked to -inf inside the kernel.
    block_v, pad_v = _pick_vocab_block(V, block_v)
    if pad_v:
        lm_head = jnp.pad(lm_head, ((0, 0), (0, pad_v)))
    nv = (V + pad_v) // block_v

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, nv, nd),
        in_specs=[
            pl.BlockSpec((1, block_d), lambda b, v, d: (b, d)),
            pl.BlockSpec((block_d, block_v), lambda b, v, d: (d, v)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, v, d: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, v, d: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_v), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.int32),
        ],
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(_verify_kernel, V=V, block_v=block_v, nv=nv, nd=nd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret_default(),
        name="specee_argmax_verify",
    )
    tok, mx = fn(hn, lm_head)
    return tok[:, 0], mx[:, 0]


# ---------------------------------------------------------------------------
# top-k verify: streaming LM-head top-k (draft proposal — propose_topk)
# ---------------------------------------------------------------------------
def _topk_kernel(h_ref, w_ref, ids_ref, vals_ref, acc_ref, run_v_ref,
                 run_i_ref, *, V: int, k: int, block_v: int, nv: int,
                 nd: int):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        run_v_ref[...] = jnp.full_like(run_v_ref, NEG_INF)
        run_i_ref[...] = jnp.zeros_like(run_i_ref)

    h = h_ref[...].astype(jnp.float32)            # (1, Dt)
    w = w_ref[...].astype(jnp.float32)            # (Dt, Vt)
    acc_ref[...] += jnp.dot(h, w, preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _fold_tile():
        col = v * block_v + jax.lax.broadcasted_iota(jnp.int32,
                                                     acc_ref.shape, 1)
        tile_v = jnp.where(col < V, acc_ref[...], NEG_INF)     # (1, Vt)
        # merged candidate pool: running list FIRST so equal values resolve
        # to the earlier (lower-id) entry under argmax's lowest-index rule
        pool_v = jnp.concatenate([run_v_ref[...], tile_v], axis=1)
        pool_i = jnp.concatenate([run_i_ref[...], col], axis=1)
        lane = jax.lax.broadcasted_iota(jnp.int32, pool_v.shape, 1)
        new_v = jnp.full((1, k), NEG_INF, jnp.float32)
        new_i = jnp.zeros((1, k), jnp.int32)
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
        for j in range(k):                         # static unroll, k is tiny
            best = jnp.max(pool_v)
            arg = jnp.argmax(pool_v[0, :]).astype(jnp.int32)
            new_v = jnp.where(slot == j, best, new_v)
            new_i = jnp.where(slot == j, pool_i[0, arg], new_i)
            pool_v = jnp.where(lane == arg, NEG_INF, pool_v)
        run_v_ref[...] = new_v
        run_i_ref[...] = new_i

        @pl.when(v == nv - 1)
        def _emit():
            ids_ref[...] = run_i_ref[...]
            vals_ref[...] = run_v_ref[...]


def topk_verify_fused(hn: jnp.ndarray, lm_head: jnp.ndarray, k: int,
                      block_v: int = 512, block_d: int = 512
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hn: (B, D); lm_head: (D, V); k: static top-k width.

    Returns (ids (B, k) int32, vals (B, k) fp32) sorted by descending
    logit (ties: ascending vocab id), with fp32 accumulation, reading the
    LM head exactly once and never materializing the (B, V) logits.
    """
    B, D = hn.shape
    V = lm_head.shape[1]
    assert k <= V, (k, V)
    block_d = _fit_block(D, block_d)
    nd = D // block_d
    block_v, pad_v = _pick_vocab_block(V, block_v)
    if pad_v:
        lm_head = jnp.pad(lm_head, ((0, 0), (0, pad_v)))
    nv = (V + pad_v) // block_v
    assert k <= block_v, (k, block_v)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, nv, nd),
        in_specs=[
            pl.BlockSpec((1, block_d), lambda b, v, d: (b, d)),
            pl.BlockSpec((block_d, block_v), lambda b, v, d: (d, v)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, v, d: (b, 0)),
            pl.BlockSpec((1, k), lambda b, v, d: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_v), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(_topk_kernel, V=V, k=k, block_v=block_v, nv=nv,
                          nd=nd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret_default(),
        name="specee_topk_verify",
    )
    ids, vals = fn(hn, lm_head)
    return ids, vals


# ---------------------------------------------------------------------------
# quantized verify: int8 / packed-int4 LM head, dequant fused into the tile
# ---------------------------------------------------------------------------
# The quantized kernels stream integer weight tiles plus a (1, block_v)
# per-column scale strip and fold the dequant into the accumulation:
# because the scale is constant down the contracted D axis,
# dot(h, q*s) == dot(h, q) * s, so each tile issues ONE integer-fed fp32
# matmul and a vector multiply — the fp weight never exists, in HBM or
# VMEM. int4 uses the plane packing from repro.quant: the packed (D/2, V)
# byte matrix holds row i in the low nibble and row i + D/2 in the high
# nibble, and the kernel receives the SAME hidden-state operand twice under
# two index maps (blocks d and d + nd) so the two planes contract against
# their own halves of h without any in-kernel interleave.

def _unpack_nibbles(p):
    """int8 packed tile -> (lo, hi) int32 sign-extended nibble planes."""
    p = p.astype(jnp.int32)
    return (p << 28) >> 28, p >> 4


def _fold_argmax(v, tile, best_ref, barg_ref, *, V, block_v):
    """Fold a finished (1, Vt) logits tile into the SMEM running argmax."""
    col = v * block_v + jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    vals = jnp.where(col < V, tile, NEG_INF)
    tmax = jnp.max(vals)
    targ = v * block_v + jnp.argmax(vals[0, :]).astype(jnp.int32)
    better = tmax > best_ref[0, 0]
    barg_ref[0, 0] = jnp.where(better, targ, barg_ref[0, 0])
    best_ref[0, 0] = jnp.where(better, tmax, best_ref[0, 0])


def _fold_topk(v, tile, run_v_ref, run_i_ref, *, V, k, block_v):
    """Fold a finished (1, Vt) logits tile into the running (1, k) top-k."""
    col = v * block_v + jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    tile_v = jnp.where(col < V, tile, NEG_INF)
    pool_v = jnp.concatenate([run_v_ref[...], tile_v], axis=1)
    pool_i = jnp.concatenate([run_i_ref[...], col], axis=1)
    lane = jax.lax.broadcasted_iota(jnp.int32, pool_v.shape, 1)
    new_v = jnp.full((1, k), NEG_INF, jnp.float32)
    new_i = jnp.zeros((1, k), jnp.int32)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    for j in range(k):
        best = jnp.max(pool_v)
        arg = jnp.argmax(pool_v[0, :]).astype(jnp.int32)
        new_v = jnp.where(slot == j, best, new_v)
        new_i = jnp.where(slot == j, pool_i[0, arg], new_i)
        pool_v = jnp.where(lane == arg, NEG_INF, pool_v)
    run_v_ref[...] = new_v
    run_i_ref[...] = new_i


def _verify_kernel_q8(h_ref, w_ref, s_ref, tok_ref, max_ref, acc_ref,
                      best_ref, barg_ref, *, V, block_v, nv, nd):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        best_ref[0, 0] = NEG_INF
        barg_ref[0, 0] = 0

    h = h_ref[...].astype(jnp.float32)                    # (1, Dt)
    w = w_ref[...].astype(jnp.float32)                    # (Dt, Vt) int8->f32
    s = s_ref[...]                                        # (1, Vt)
    acc_ref[...] += jnp.dot(h, w, preferred_element_type=jnp.float32) * s

    @pl.when(d == nd - 1)
    def _fold_tile():
        _fold_argmax(v, acc_ref[...], best_ref, barg_ref, V=V,
                     block_v=block_v)

        @pl.when(v == nv - 1)
        def _emit():
            tok_ref[...] = jnp.full((1, 1), barg_ref[0, 0], jnp.int32)
            max_ref[...] = jnp.full((1, 1), best_ref[0, 0], jnp.float32)


def _verify_kernel_q4(hlo_ref, hhi_ref, w_ref, s_ref, tok_ref, max_ref,
                      acc_ref, best_ref, barg_ref, *, V, block_v, nv, nd):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        best_ref[0, 0] = NEG_INF
        barg_ref[0, 0] = 0

    h_lo = hlo_ref[...].astype(jnp.float32)               # (1, Dt) rows d
    h_hi = hhi_ref[...].astype(jnp.float32)               # (1, Dt) rows d+D/2
    lo, hi = _unpack_nibbles(w_ref[...])                  # (Dt, Vt) planes
    s = s_ref[...]                                        # (1, Vt)
    part = (jnp.dot(h_lo, lo.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            + jnp.dot(h_hi, hi.astype(jnp.float32),
                      preferred_element_type=jnp.float32))
    acc_ref[...] += part * s

    @pl.when(d == nd - 1)
    def _fold_tile():
        _fold_argmax(v, acc_ref[...], best_ref, barg_ref, V=V,
                     block_v=block_v)

        @pl.when(v == nv - 1)
        def _emit():
            tok_ref[...] = jnp.full((1, 1), barg_ref[0, 0], jnp.int32)
            max_ref[...] = jnp.full((1, 1), best_ref[0, 0], jnp.float32)


def _topk_kernel_q8(h_ref, w_ref, s_ref, ids_ref, vals_ref, acc_ref,
                    run_v_ref, run_i_ref, *, V, k, block_v, nv, nd):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        run_v_ref[...] = jnp.full_like(run_v_ref, NEG_INF)
        run_i_ref[...] = jnp.zeros_like(run_i_ref)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += (jnp.dot(h, w, preferred_element_type=jnp.float32)
                     * s_ref[...])

    @pl.when(d == nd - 1)
    def _fold_tile():
        _fold_topk(v, acc_ref[...], run_v_ref, run_i_ref, V=V, k=k,
                   block_v=block_v)

        @pl.when(v == nv - 1)
        def _emit():
            ids_ref[...] = run_i_ref[...]
            vals_ref[...] = run_v_ref[...]


def _topk_kernel_q4(hlo_ref, hhi_ref, w_ref, s_ref, ids_ref, vals_ref,
                    acc_ref, run_v_ref, run_i_ref, *, V, k, block_v, nv, nd):
    v = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((v == 0) & (d == 0))
    def _init_row():
        run_v_ref[...] = jnp.full_like(run_v_ref, NEG_INF)
        run_i_ref[...] = jnp.zeros_like(run_i_ref)

    h_lo = hlo_ref[...].astype(jnp.float32)
    h_hi = hhi_ref[...].astype(jnp.float32)
    lo, hi = _unpack_nibbles(w_ref[...])
    part = (jnp.dot(h_lo, lo.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            + jnp.dot(h_hi, hi.astype(jnp.float32),
                      preferred_element_type=jnp.float32))
    acc_ref[...] += part * s_ref[...]

    @pl.when(d == nd - 1)
    def _fold_tile():
        _fold_topk(v, acc_ref[...], run_v_ref, run_i_ref, V=V, k=k,
                   block_v=block_v)

        @pl.when(v == nv - 1)
        def _emit():
            ids_ref[...] = run_i_ref[...]
            vals_ref[...] = run_v_ref[...]


def _q_verify_plan(hn, qt, block_v, block_d):
    """Shared launch geometry for the quantized verify/topk kernels.

    Returns (operands, in_specs, grid, block_v, V) where operands already
    carry any vocab padding (int8 zero columns + zero scales — masked to
    NEG_INF by the fold, exactly like the fp kernels' pad path).
    """
    B, D = hn.shape
    q = qt.q
    V = q.shape[-1]
    scale = qt.scale.reshape(1, V)
    if qt.bits == 4:
        assert q.shape[0] * 2 == D, (q.shape, D)
        block_d = _fit_block(q.shape[0], block_d)
        nd = q.shape[0] // block_d
    else:
        assert q.shape[0] == D, (q.shape, D)
        block_d = _fit_block(D, block_d)
        nd = D // block_d
    block_v, pad_v = _pick_vocab_block(V, block_v)
    if pad_v:
        q = jnp.pad(q, ((0, 0), (0, pad_v)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_v)))
    nv = (V + pad_v) // block_v

    w_spec = pl.BlockSpec((block_d, block_v), lambda b, v, d: (d, v))
    s_spec = pl.BlockSpec((1, block_v), lambda b, v, d: (0, v))
    if qt.bits == 4:
        # the SAME hn operand twice: plane-packed halves contract against
        # h[:, :D/2] (block d) and h[:, D/2:] (block d + nd)
        in_specs = [
            pl.BlockSpec((1, block_d), lambda b, v, d: (b, d)),
            pl.BlockSpec((1, block_d), lambda b, v, d, nd=nd: (b, d + nd)),
            w_spec, s_spec,
        ]
        operands = (hn, hn, q, scale)
    else:
        in_specs = [pl.BlockSpec((1, block_d), lambda b, v, d: (b, d)),
                    w_spec, s_spec]
        operands = (hn, q, scale)
    return operands, in_specs, (B, nv, nd), (block_v, nv, nd), V


def argmax_verify_fused_q(hn: jnp.ndarray, qt, block_v: int = 512,
                          block_d: int = 512
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized-LM-head streaming argmax. hn: (B, D); qt: QTensor whose
    logical shape is (D, V). Numerics: identical to running
    ``argmax_verify_fused(hn, qt.dequantize())`` (fp32 accumulation, scale
    folded after the tile dot — exact because scales are per-column).
    """
    B = hn.shape[0]
    operands, in_specs, grid, (block_v, nv, nd), V = _q_verify_plan(
        hn, qt, block_v, block_d)
    kernel = _verify_kernel_q4 if qt.bits == 4 else _verify_kernel_q8

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, v, d: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, v, d: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_v), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
            pltpu.SMEM((1, 1), jnp.int32),
        ],
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(kernel, V=V, block_v=block_v, nv=nv, nd=nd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret_default(),
        name=f"specee_argmax_verify_q{qt.bits}",
    )
    tok, mx = fn(*operands)
    return tok[:, 0], mx[:, 0]


def topk_verify_fused_q(hn: jnp.ndarray, qt, k: int, block_v: int = 512,
                        block_d: int = 512
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized-LM-head streaming top-k (draft proposal path). Same
    ordering contract as ``topk_verify_fused`` on the dequantized head.
    """
    B = hn.shape[0]
    operands, in_specs, grid, (block_v, nv, nd), V = _q_verify_plan(
        hn, qt, block_v, block_d)
    assert k <= min(qt.shape[-1], block_v), (k, qt.shape, block_v)
    kernel = _topk_kernel_q4 if qt.bits == 4 else _topk_kernel_q8

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), lambda b, v, d: (b, 0)),
            pl.BlockSpec((1, k), lambda b, v, d: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_v), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(kernel, V=V, k=k, block_v=block_v, nv=nv, nd=nd),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret_default(),
        name=f"specee_topk_verify_q{qt.bits}",
    )
    ids, vals = fn(*operands)
    return ids, vals
