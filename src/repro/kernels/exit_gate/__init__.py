"""Fused exit-gate pipeline — the decode hot loop's per-exit-point cost.

SpecEE's speedup claim (paper §6.2, §7.3) holds only while the exit decision
costs a small fraction of one transformer unit. The reference decode loop
runs the gate as four separate XLA ops:

  1. spec-head gather-GEMM      — k LM-head columns · hidden  -> (B, k) logits
  2. softmax + Δ-feature concat — (B, 3k) predictor features
  3. predictor MLP + sigmoid    — (B,) exit probability
  4. verification               — FULL LM head (B, V) fp32 logits, argmax,
                                  membership test against the speculative set

This package fuses that pipeline into at most TWO Pallas calls per exit
point:

  ``exit_gate``     — one kernel chaining (1)+(2)+(3): scalar-prefetched
                      column gather, per-row k-GEMM accumulation, softmax,
                      Δ-features and the 2-layer MLP, with the (B, 3k)
                      features never leaving VMEM.
  ``argmax_verify`` — streaming LM-head argmax for (4): tiles over the vocab
                      dimension keeping only a running (max, argmax) per row,
                      so the full (B, V) fp32 logits are NEVER materialized.

HBM-traffic accounting per exit point (weights dtype bytes ``w``, fp32
activations), B rows, hidden D, vocab V, k speculative tokens:

  reference gate:   k·D·w   (column gather)
  reference verify: D·V·w   (LM-head read)  +  B·V·4 write + B·V·4 read
                    (materialized logits)   +  B·V·4 read (argmax pass)
  fused gate:       k·D·w   (same gather — already minimal)
  fused verify:     D·V·w   (ONE LM-head pass; running max/argmax live in
                    VMEM/SMEM scratch, no logits round-trip)

For Llama2-7B decode (D=4096, V=32000, bf16 weights, B=8) the eliminated
logits round-trips are 3·B·V·4 ≈ 3.1 MB per exit point — on top of removing
three kernel-launch/dispatch boundaries. The reference four-op path is kept
bit-for-bit intact behind the same entry points (``impl="ref"``) and is the
oracle for the parity tests in ``tests/test_exit_gate.py``.

Files: ``exit_gate.py`` (Pallas kernels), ``ops.py`` (jit'd public wrappers +
impl selection + stacked-predictor-bank routing), ``ref.py`` (pure-jnp
oracles).
"""
