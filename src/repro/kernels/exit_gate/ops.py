"""Public jit'd wrappers for the fused exit gate.

``exit_gate()`` / ``verify_argmax()`` are the decode engine's SINGLE entry
points for the per-exit-point decision; an ``impl`` switch selects the
backend:

  "kernel" — the Pallas chain (interpret mode off-TPU): gate = one fused
             kernel, verify = the streaming argmax kernel.
  "xla"    — the same fused dataflow as one XLA computation: gate is the
             jnp chain under a single jit; verify streams vocab tiles with a
             ``lax.scan`` running (max, argmax) — still never materializes
             the (B, V) logits.
  "ref"    — the engine's historical unfused op sequence, bit-for-bit
             (verification matmuls in ``hn.dtype``). The numerics reference.
  None / "auto" — "kernel" on TPU; off-TPU the gate takes "xla" and the
             verify takes "ref" (on CPU one BLAS GEMM beats any streaming
             formulation — the logits-round-trip saving is an HBM property).

The stacked predictor bank is routed THROUGH the wrapper: ``exit_gate``
takes the full ``(E, ...)`` bank plus the exit-point index and performs the
``dynamic_index_in_dim`` inside the same jit as the kernel launch, so the
per-step weight slice fuses with the gate instead of bouncing through HBM.
Predictor banks that are not 2-layer (DSE sweeps) fall back from "kernel"
to the jnp chain automatically.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.exit_gate import ref as gate_ref
from repro.kernels.exit_gate import tuning
from repro.kernels.exit_gate.exit_gate import (argmax_verify_fused,
                                               argmax_verify_fused_q,
                                               exit_gate_fused,
                                               topk_verify_fused_q)
from repro.quant import QTensor, unpack_int4

IMPLS = (None, "auto", "kernel", "xla", "ref")

_I32_MAX = 2**31 - 1


def resolve_impl(impl: Optional[str], cpu_default: str = "xla") -> str:
    """Backend an ``impl`` request resolves to on the current platform."""
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl in (None, "auto"):
        return "kernel" if on_tpu() else cpu_default
    return impl


_resolve = resolve_impl


def impl_for_flags(flags) -> str:
    """Exit-gate backend a ``ModelFlags`` bundle selects.

    This is THE single resolution point for ``ModelFlags.exit_gate_kernel``:
    the decode strategies (repro.api), the engine step functions, and the
    draft proposal all call it instead of re-reading the flags at every call
    site. With the flag off every entry point pins the historical "ref"
    numerics bit-for-bit.
    """
    fused = getattr(flags, "exit_gate_kernel", False)
    return (getattr(flags, "exit_gate_impl", "auto") or "auto") if fused \
        else "ref"


def _index_bank(predictors, ep):
    """Slice one predictor out of the stacked (E, ...) bank."""
    from repro.core.predictor import predictor_at
    return predictor_at(predictors, ep)


@partial(jax.jit, static_argnames=("impl", "spec_head_kernel", "block_d"))
def exit_gate(hn: jnp.ndarray, lm_head, spec_ids: jnp.ndarray,
              prev_probs: jnp.ndarray, predictors, ep: jnp.ndarray,
              impl: Optional[str] = None, spec_head_kernel: bool = False,
              block_d: int = 512
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused exit decision for one exit point.

    hn: (B, D) final-normed hidden; lm_head: (D, V); spec_ids: (B, k) int32;
    prev_probs: (B, k); predictors: stacked bank (every leaf (E, ...));
    ep: scalar int32 exit-point index.

    Returns (p_exit (B,), local_probs (B, k), logits (B, k)), all fp32.
    """
    impl = _resolve(impl)
    pp = _index_bank(predictors, ep)
    layers = pp["layers"]
    quantized = (isinstance(lm_head, QTensor)
                 or any(isinstance(l.get("w"), QTensor) for l in layers))
    if impl == "kernel" and len(layers) == 2 and quantized:
        # piecewise fusion for quantized weights (mirrors the tree gate):
        # the quantized spec-head gather kernel + the quantized fused MLP —
        # features still make exactly one VMEM round-trip each
        from repro.kernels.predictor_mlp import ops as pm_ops
        from repro.kernels.spec_head import ops as sh_ops
        logits, probs = sh_ops.spec_head(hn, lm_head, spec_ids,
                                         block_d=block_d)
        feats = jnp.concatenate(
            [logits, probs, probs - prev_probs.astype(jnp.float32)], axis=-1)
        return pm_ops.predictor_mlp(feats, pp), probs, logits
    if impl == "kernel" and len(layers) == 2:
        return exit_gate_fused(hn, lm_head, spec_ids, prev_probs,
                               layers[0]["w"], layers[0]["b"],
                               layers[1]["w"], layers[1]["b"],
                               block_d=block_d)
    if impl == "ref" and spec_head_kernel:
        # historical path with the spec_head Pallas kernel selected
        from repro.kernels.spec_head import ops as sh_ops
        logits, probs = sh_ops.spec_head(hn, lm_head, spec_ids)
        feats = jnp.concatenate(
            [logits, probs, probs - prev_probs.astype(jnp.float32)], axis=-1)
        return gate_ref.mlp_ref(feats, pp), probs, logits
    # "xla" and "ref" share the jnp dataflow — under this jit XLA fuses it
    # into one computation either way; "ref" exists so callers can pin the
    # historical numerics explicitly.
    return gate_ref.exit_gate_ref(hn, lm_head, spec_ids, prev_probs, pp)


def _verify_streaming_xla(hn: jnp.ndarray, lm_head: jnp.ndarray,
                          block_v: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """lax.scan over vocab tiles with a running (max, argmax) carry."""
    from repro.kernels.exit_gate.exit_gate import _pick_vocab_block
    B, D = hn.shape
    V = lm_head.shape[1]
    # same no-copy preference as the kernel: only pad for vocabs where no
    # reasonable block divides V
    block_v, pad_v = _pick_vocab_block(V, block_v)
    wp = jnp.pad(lm_head, ((0, 0), (0, pad_v))) if pad_v else lm_head
    nv = (V + pad_v) // block_v
    hf = hn.astype(jnp.float32)
    lanes = jnp.arange(block_v)

    def body(carry, v):
        best, barg = carry
        w = jax.lax.dynamic_slice_in_dim(wp, v * block_v, block_v, axis=1)
        tile = hf @ w.astype(jnp.float32)                      # (B, Vt)
        col = v * block_v + lanes
        tile = jnp.where(col[None, :] < V, tile, -jnp.inf)
        tmax = jnp.max(tile, axis=-1)
        targ = (v * block_v + jnp.argmax(tile, axis=-1)).astype(jnp.int32)
        better = tmax > best
        return (jnp.where(better, tmax, best),
                jnp.where(better, targ, barg)), None

    init = (jnp.full((B,), -jnp.inf, jnp.float32),
            jnp.zeros((B,), jnp.int32))
    (best, barg), _ = jax.lax.scan(body, init, jnp.arange(nv))
    return barg, best


def _q_stream_plan(hn: jnp.ndarray, qt: QTensor, block_v: int):
    """Shared tile geometry + per-tile dequantized-logits fn for the
    quantized streaming-XLA paths. Mirrors the quantized kernels: integer
    codes + per-column scales stream per tile; the scale folds in after
    the dot (exact — scales are column-constant)."""
    from repro.kernels.exit_gate.exit_gate import _pick_vocab_block
    V = qt.q.shape[-1]
    block_v, pad_v = _pick_vocab_block(V, block_v)
    q = qt.q
    scale = qt.scale
    if pad_v:
        q = jnp.pad(q, ((0, 0), (0, pad_v)))
        scale = jnp.pad(scale, (0, pad_v))
    nv = (V + pad_v) // block_v
    hf = hn.astype(jnp.float32)
    half = q.shape[0]        # = D/2 for the packed int4 plane layout
    bits = qt.bits

    def tile_logits(v):
        qt_tile = jax.lax.dynamic_slice_in_dim(q, v * block_v, block_v,
                                               axis=1)
        s_tile = jax.lax.dynamic_slice_in_dim(scale, v * block_v, block_v,
                                              axis=0)
        if bits == 4:
            lo, hi = unpack_int4(qt_tile)
            part = (hf[:, :half] @ lo.astype(jnp.float32)
                    + hf[:, half:] @ hi.astype(jnp.float32))
        else:
            part = hf @ qt_tile.astype(jnp.float32)
        return part * s_tile[None, :]                   # (B, Vt)

    return tile_logits, block_v, nv, V


def _verify_streaming_xla_q(hn: jnp.ndarray, qt: QTensor,
                            block_v: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized sibling of ``_verify_streaming_xla``."""
    B = hn.shape[0]
    tile_logits, block_v, nv, V = _q_stream_plan(hn, qt, block_v)
    lanes = jnp.arange(block_v)

    def body(carry, v):
        best, barg = carry
        col = v * block_v + lanes
        tile = jnp.where(col[None, :] < V, tile_logits(v), -jnp.inf)
        tmax = jnp.max(tile, axis=-1)
        targ = (v * block_v + jnp.argmax(tile, axis=-1)).astype(jnp.int32)
        better = tmax > best
        return (jnp.where(better, tmax, best),
                jnp.where(better, targ, barg)), None

    init = (jnp.full((B,), -jnp.inf, jnp.float32),
            jnp.zeros((B,), jnp.int32))
    (best, barg), _ = jax.lax.scan(body, init, jnp.arange(nv))
    return barg, best


def _topk_streaming_xla_q(hn: jnp.ndarray, qt: QTensor, k: int,
                          block_v: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized sibling of ``_topk_streaming_xla`` (same tie contract)."""
    B = hn.shape[0]
    tile_logits, block_v, nv, V = _q_stream_plan(hn, qt, block_v)
    lanes = jnp.arange(block_v)

    def body(carry, v):
        cvals, cids = carry
        col = v * block_v + lanes
        tile = jnp.where(col[None, :] < V, tile_logits(v), -jnp.inf)
        pool_v = jnp.concatenate([cvals, tile], axis=1)
        pool_i = jnp.concatenate(
            [cids, jnp.broadcast_to(col[None, :], tile.shape)], axis=1)
        nvals, sel = jax.lax.top_k(pool_v, k)
        nids = jnp.take_along_axis(pool_i, sel, axis=1)
        return (nvals, nids.astype(jnp.int32)), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, ids), _ = jax.lax.scan(body, init, jnp.arange(nv))
    return ids, vals


# ---------------------------------------------------------------------------
# sharded verify (tensor-parallel LM head, DESIGN.md §9)
#
# The vocab dimension shards over ``shard.axis``; the D contraction never
# splits, so every per-column logit a shard computes is bit-identical to the
# single-device value. Each shard reduces its local slice to a tiny partial
# — (max, argmax) or top-k — inside a purely-local ``shard_map`` body (no
# collectives; partials concatenate along a leading axis via out_specs), and
# one (P, B)-sized merge outside reproduces the global tie-break contract:
# lowest global id among equal maxima (= ``jnp.argmax`` first-occurrence),
# and ``lax.top_k``'s lower-index-first ordering for top-k.
# ---------------------------------------------------------------------------
def _shard_pad(lm_head: jnp.ndarray, degree: int):
    """Pad the (D, V) head so the vocab splits evenly: -> (padded head,
    per-shard width, pad columns added). Padded columns are zeros and MUST be
    masked before any reduction — a zero logit can beat real negatives."""
    V = lm_head.shape[1]
    width = -(-V // degree)
    pad = width * degree - V
    if pad:
        lm_head = jnp.pad(lm_head, ((0, 0), (0, pad)))
    return lm_head, width, pad


def _masked_slice_logits(hn, w_local, col0, v_total, dt):
    """Materialized logits for one vocab slice with padding masked to -inf.
    ``col0`` is the slice's first GLOBAL column (traced: axis_index * width);
    ``v_total`` the unpadded vocab size. Matmul in ``dt`` then fp32, the
    exact compute path of ``verify_*_ref`` (dt=hn.dtype) and of the fp32
    streaming impls (dt=float32)."""
    logits = (hn.astype(dt) @ w_local.astype(dt)).astype(jnp.float32)
    col = col0 + jnp.arange(w_local.shape[1], dtype=jnp.int32)
    return jnp.where(col[None, :] < v_total, logits, -jnp.inf)


def _local_dtype(impl, hn):
    # "ref" verifies in hn.dtype (the historical materialized matmul);
    # "xla"/"kernel" accumulate in fp32 (the streaming contract)
    return hn.dtype if impl == "ref" else jnp.float32


def _verify_argmax_sharded(hn, lm_head, shard, impl, block_v, block_d):
    from repro.sharding import compat
    P = jax.sharding.PartitionSpec
    degree = shard.degree
    wp, width, pad = _shard_pad(lm_head, degree)
    V = lm_head.shape[1]
    if block_v is None:
        block_v = tuning.best_block_v(hn.shape[1], width)

    def local(hn, w_local):
        # per-shard partial (argmax, max) over the local vocab slice; token
        # ids are GLOBAL. With padding the masked materialized form is used
        # for every impl (the pad mask must see global column ids).
        col0 = jax.lax.axis_index(shard.axis).astype(jnp.int32) * width
        if pad:
            logits = _masked_slice_logits(hn, w_local, col0, V,
                                          _local_dtype(impl, hn))
            tok = col0 + jnp.argmax(logits, axis=-1).astype(jnp.int32)
            val = jnp.max(logits, axis=-1)
        elif impl == "kernel":
            tok, val = argmax_verify_fused(hn, w_local, block_v=block_v,
                                           block_d=block_d)
            tok = tok + col0
        elif impl == "xla":
            tok, val = _verify_streaming_xla(hn, w_local, block_v)
            tok = tok + col0
        else:
            tok, val = gate_ref.verify_argmax_ref(hn, w_local,
                                                  compute_dtype=hn.dtype)
            tok = tok + col0
        return tok[None], val[None]                        # (1, B) partials

    toks, vals = compat.shard_map_unchecked(
        local, shard.mesh,
        in_specs=(P(), P(None, shard.axis)),
        out_specs=(P(shard.axis), P(shard.axis)))(hn, wp)
    # merge (P, B) partials: max value wins; equal maxima take the lowest
    # global token id — jnp.argmax's first-occurrence contract on the full
    # logits (a fully-padded shard reports -inf and never wins)
    best = jnp.max(vals, axis=0)
    cand = jnp.where(vals == best[None, :], toks, _I32_MAX)
    return jnp.min(cand, axis=0).astype(jnp.int32), best


def _verify_topk_sharded(hn, lm_head, k, shard, impl, block_v, block_d):
    from repro.sharding import compat
    P = jax.sharding.PartitionSpec
    degree = shard.degree
    wp, width, pad = _shard_pad(lm_head, degree)
    V = lm_head.shape[1]
    if k > width:
        raise ValueError(
            f"verify_topk: k={k} exceeds the per-shard vocab slice "
            f"({V} cols / {degree} shards = {width}); every global top-k "
            "entry must be inside its shard's local top-k")
    if block_v is None:
        block_v = tuning.best_block_v(hn.shape[1], width)

    def local(hn, w_local):
        col0 = jax.lax.axis_index(shard.axis).astype(jnp.int32) * width
        if pad:
            logits = _masked_slice_logits(hn, w_local, col0, V,
                                          _local_dtype(impl, hn))
            vals, sel = jax.lax.top_k(logits, k)
            ids = col0 + sel.astype(jnp.int32)
        elif impl == "kernel":
            from repro.kernels.exit_gate.exit_gate import topk_verify_fused
            ids, vals = topk_verify_fused(hn, w_local, k, block_v=block_v,
                                          block_d=block_d)
            ids = ids + col0
        elif impl == "xla":
            ids, vals = _topk_streaming_xla(hn, w_local, k, block_v)
            ids = ids + col0
        else:
            ids, vals = gate_ref.verify_topk_ref(hn, w_local, k,
                                                 compute_dtype=hn.dtype)
            ids = ids + col0
        return ids[None], vals[None]                      # (1, B, k)

    ids, vals = compat.shard_map_unchecked(
        local, shard.mesh,
        in_specs=(P(), P(None, shard.axis)),
        out_specs=(P(shard.axis), P(shard.axis)))(hn, wp)
    # (P, B, k) -> shard-major (B, P·k) pool: within a shard local top-k is
    # id-ascending among equal values and shards are id-ascending, so
    # lax.top_k's lower-index-first tie-break reproduces the global contract
    B = hn.shape[0]
    pool_v = jnp.transpose(vals, (1, 0, 2)).reshape(B, degree * k)
    pool_i = jnp.transpose(ids, (1, 0, 2)).reshape(B, degree * k)
    nvals, sel = jax.lax.top_k(pool_v, k)
    nids = jnp.take_along_axis(pool_i, sel, axis=1)
    return nids.astype(jnp.int32), nvals


@partial(jax.jit, static_argnames=("impl", "block_v", "block_d", "shard"))
def verify_argmax(hn: jnp.ndarray, lm_head,
                  impl: Optional[str] = None, block_v: Optional[int] = None,
                  block_d: int = 512, shard=None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-LM-head argmax for verification. hn: (B, D); lm_head: (D, V).

    "kernel"/"xla" stream the vocab dimension with fp32 accumulation and
    never materialize (B, V); "ref" is the engine's historical materialized
    matmul in ``hn.dtype``. Auto resolves to "kernel" on TPU (where the
    saved logits round-trips are HBM traffic) and to "ref" on CPU, where
    one BLAS GEMM beats any streaming formulation and the memory win is
    moot. ``block_v=None`` takes the autotuned vocab-strip width for this
    (D, V) from ``tuning.best_block_v`` (swept by ``hillclimb.py
    --gate-blocks``, cached in repro/configs/gate_blocks.json).
    ``shard``: optional ``repro.sharding.ctx.ShardCtx`` — verify as a
    per-shard partial reduction over the local vocab slice + one tiny merge
    (bit-identical to single-device under any vocab split; see DESIGN.md
    §9). Quantized heads stay on the unsharded path (QTensor tiles ride
    replicated under a mesh).
    Returns (token (B,) int32, max logit (B,) fp32).
    """
    impl = _resolve(impl, cpu_default="ref")
    if shard is not None and not isinstance(lm_head, QTensor):
        return _verify_argmax_sharded(hn, lm_head, shard, impl, block_v,
                                      block_d)
    if isinstance(lm_head, QTensor):
        if block_v is None:
            block_v = tuning.best_block_v(hn.shape[1], lm_head.shape[-1],
                                          wbits=lm_head.bits)
        if impl == "kernel":
            return argmax_verify_fused_q(hn, lm_head, block_v=block_v,
                                         block_d=block_d)
        if impl == "xla":
            return _verify_streaming_xla_q(hn, lm_head, block_v)
        return gate_ref.verify_argmax_ref(hn, lm_head,
                                          compute_dtype=hn.dtype)
    if block_v is None:
        block_v = tuning.best_block_v(hn.shape[1], lm_head.shape[1])
    if impl == "kernel":
        return argmax_verify_fused(hn, lm_head, block_v=block_v,
                                   block_d=block_d)
    if impl == "xla":
        return _verify_streaming_xla(hn, lm_head, block_v)
    return gate_ref.verify_argmax_ref(hn, lm_head, compute_dtype=hn.dtype)


def _topk_streaming_xla(hn: jnp.ndarray, lm_head: jnp.ndarray, k: int,
                        block_v: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """lax.scan over vocab tiles with a running (vals, ids) top-k carry.

    The carry is prepended to each tile before ``top_k`` so ties resolve to
    the earlier (lower-id) entry — bit-matching ``jax.lax.top_k`` on the
    materialized logits.
    """
    from repro.kernels.exit_gate.exit_gate import _pick_vocab_block
    B, D = hn.shape
    V = lm_head.shape[1]
    block_v, pad_v = _pick_vocab_block(V, block_v)
    wp = jnp.pad(lm_head, ((0, 0), (0, pad_v))) if pad_v else lm_head
    nv = (V + pad_v) // block_v
    hf = hn.astype(jnp.float32)
    lanes = jnp.arange(block_v)

    def body(carry, v):
        cvals, cids = carry                                    # (B, k) each
        w = jax.lax.dynamic_slice_in_dim(wp, v * block_v, block_v, axis=1)
        tile = hf @ w.astype(jnp.float32)                      # (B, Vt)
        col = v * block_v + lanes
        tile = jnp.where(col[None, :] < V, tile, -jnp.inf)
        pool_v = jnp.concatenate([cvals, tile], axis=1)        # (B, k+Vt)
        pool_i = jnp.concatenate(
            [cids, jnp.broadcast_to(col[None, :], tile.shape)], axis=1)
        nvals, sel = jax.lax.top_k(pool_v, k)
        nids = jnp.take_along_axis(pool_i, sel, axis=1)
        return (nvals, nids.astype(jnp.int32)), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, ids), _ = jax.lax.scan(body, init, jnp.arange(nv))
    return ids, vals


@partial(jax.jit,
         static_argnames=("k", "impl", "block_v", "block_d", "shard"))
def verify_topk(hn: jnp.ndarray, lm_head, k: int,
                impl: Optional[str] = None, block_v: Optional[int] = None,
                block_d: int = 512, shard=None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-LM-head top-k — the streaming sibling of ``verify_argmax`` for
    the draft proposal path. hn: (B, D); lm_head: (D, V).

    "kernel"/"xla" tile the vocab keeping a running per-row top-k with fp32
    accumulation and never materialize (B, V); "ref" is ``propose_topk``'s
    historical materialized matmul in ``hn.dtype`` + ``jax.lax.top_k``. Auto
    resolves like ``verify_argmax`` (kernel on TPU, ref on CPU).
    ``block_v=None`` takes the autotuned strip width (the top-k kernel
    shares the argmax kernel's tiling knobs — same sweep, same table).
    ``shard``: optional ShardCtx — per-shard partial top-k over the local
    vocab slice merged by one tiny ``lax.top_k`` over the (B, P·k) pool
    (same tie contract as the single-device path; see ``verify_argmax``).
    Returns (ids (B, k) int32, vals (B, k) fp32), descending by logit.
    """
    impl = _resolve(impl, cpu_default="ref")
    if shard is not None and not isinstance(lm_head, QTensor):
        return _verify_topk_sharded(hn, lm_head, k, shard, impl, block_v,
                                    block_d)
    if isinstance(lm_head, QTensor):
        if block_v is None:
            block_v = tuning.best_block_v(hn.shape[1], lm_head.shape[-1],
                                          wbits=lm_head.bits)
        if impl == "kernel":
            return topk_verify_fused_q(hn, lm_head, k, block_v=block_v,
                                       block_d=block_d)
        if impl == "xla":
            return _topk_streaming_xla_q(hn, lm_head, k, block_v)
        return gate_ref.verify_topk_ref(hn, lm_head, k,
                                        compute_dtype=hn.dtype)
    if block_v is None:
        block_v = tuning.best_block_v(hn.shape[1], lm_head.shape[1])
    if impl == "kernel":
        from repro.kernels.exit_gate.exit_gate import topk_verify_fused
        return topk_verify_fused(hn, lm_head, k, block_v=block_v,
                                 block_d=block_d)
    if impl == "xla":
        return _topk_streaming_xla(hn, lm_head, k, block_v)
    return gate_ref.verify_topk_ref(hn, lm_head, k, compute_dtype=hn.dtype)
