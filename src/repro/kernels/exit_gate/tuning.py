"""Verify-kernel vocab-tile autotuning (ROADMAP: block_v was a guess).

The streaming argmax-verify and top-k-verify kernels tile the vocab axis in
``block_v``-column strips; the best strip width depends on (D, V) and the
backend (VMEM residency vs. grid overhead on TPU, scan-step overhead on the
XLA-CPU streaming path). ``benchmarks/hillclimb.py --gate-blocks`` sweeps
the candidates per shape with the same measured-not-estimated harness as the
roofline cells and caches the winners in ``repro/configs/gate_blocks.json``,
keyed by backend:

    {"cpu": {"1024x16000": 1024, ...}, "tpu": {...}}

``best_block_v`` consults that table (exact shape first, then the
log-distance-nearest swept shape) and falls back to the historical default
of 512 when nothing applies. The argmax and top-k kernels share the tiling
knobs — one sweep serves both (the sweep scores their combined runtime).
"""
from __future__ import annotations

import json
import math
import os
from functools import lru_cache
from typing import Dict, Optional

DEFAULT_BLOCK_V = 512

# candidate strip widths the sweep explores (powers of two spanning "many
# tiny grid steps" to "one strip is most of a small vocab")
BLOCK_V_CANDIDATES = (128, 256, 512, 1024, 2048)

TABLE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "configs", "gate_blocks.json")


@lru_cache(maxsize=None)
def _table() -> Dict[str, Dict[str, int]]:
    try:
        with open(TABLE_PATH) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def reload_table() -> None:
    """Drop the cached table (after a sweep rewrites the JSON)."""
    _table.cache_clear()


def best_block_v(d_model: int, vocab: int, backend: Optional[str] = None,
                 wbits: Optional[int] = None) -> int:
    """The swept vocab-strip width for a (D, V) verify shape.

    ``wbits`` selects the quantized-kernel sweeps (keys carry an ``@q8`` /
    ``@q4`` suffix — the int tiles change the VMEM-residency trade-off, so
    they are swept separately). Exact table hit wins; otherwise the nearest
    same-family swept shape by log-space distance (tile choice tracks
    scale, not exact dims); a quantized lookup with no quantized entries
    falls back to the fp table; otherwise the historical default of 512.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    entries = _table().get(backend, {})
    if not entries:
        return DEFAULT_BLOCK_V
    suffix = f"@q{wbits}" if wbits else ""
    key = f"{d_model}x{vocab}{suffix}"
    if key in entries:
        return int(entries[key])

    def family(sfx: str) -> Dict[str, int]:
        return {k: v for k, v in entries.items()
                if (k.endswith(sfx) if sfx else "@" not in k)}

    pool = family(suffix) or family("")
    if not pool:
        return DEFAULT_BLOCK_V

    def dist(k: str) -> float:
        d, v = (int(x) for x in k.split("@")[0].split("x"))
        return (abs(math.log(d_model / d)) + abs(math.log(vocab / v)))

    return int(pool[min(pool, key=dist)])
