"""Pure-jnp oracles for the fused exit gate (allclose tests + ref impl).

``exit_gate_ref`` reproduces the decode engine's historical four-stage gate
by DELEGATING to the canonical implementations (``spec_head_ref`` for the
gather-GEMM + softmax, ``repro.core.predictor.apply_predictor`` for the MLP)
— the oracle cannot drift from the ops the engine's reference path is made
of. ``verify_argmax_ref`` reproduces the historical verification (full-head
matmul in ``compute_dtype`` then fp32 argmax).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.predictor import apply_predictor
from repro.kernels.spec_head.ref import spec_head_ref


def mlp_ref(feats: jnp.ndarray, predictor) -> jnp.ndarray:
    """predictor: {"layers": [{w,b}, ...]} (repro.core.predictor layout,
    single bank entry) -> (B,) exit probability."""
    return apply_predictor(predictor, feats)


def exit_gate_ref(hn: jnp.ndarray, lm_head: jnp.ndarray,
                  spec_ids: jnp.ndarray, prev_probs: jnp.ndarray,
                  predictor) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The unfused gate: returns (p_exit (B,), probs (B, k), logits (B, k))."""
    logits, probs = spec_head_ref(hn, lm_head, spec_ids)
    feats = jnp.concatenate([logits, probs,
                             probs - prev_probs.astype(jnp.float32)], axis=-1)
    return apply_predictor(predictor, feats), probs, logits


def _materialize(lm_head):
    """Dequantize a QTensor head — the quantized paths' numerics oracle."""
    from repro.quant import QTensor
    if isinstance(lm_head, QTensor):
        return lm_head.dequantize()
    return lm_head


def verify_argmax_ref(hn: jnp.ndarray, lm_head,
                      compute_dtype: Optional[jnp.dtype] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-head argmax via materialized (B, V) logits.

    compute_dtype=None accumulates in fp32 (the kernel's contract);
    compute_dtype=hn.dtype is the engine's historical behaviour. A
    ``repro.quant.QTensor`` head is dequantized first — this IS the
    bit-exactness oracle the fused quantized kernels are tested against.
    Returns (token (B,) int32, max logit (B,) fp32).
    """
    lm_head = _materialize(lm_head)
    dt = jnp.float32 if compute_dtype is None else compute_dtype
    logits = (hn.astype(dt) @ lm_head.astype(dt)).astype(jnp.float32)
    return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
            jnp.max(logits, axis=-1))


def verify_topk_ref(hn: jnp.ndarray, lm_head, k: int,
                    compute_dtype: Optional[jnp.dtype] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-head top-k via materialized (B, V) logits (``jax.lax.top_k``).

    compute_dtype=None accumulates in fp32 (the kernel's contract);
    compute_dtype=hn.dtype is ``propose_topk``'s historical behaviour
    (``model.logits`` matmuls in the activation dtype). QTensor heads are
    dequantized first (quantized-kernel oracle).
    Returns (ids (B, k) int32, vals (B, k) fp32).
    """
    lm_head = _materialize(lm_head)
    dt = jnp.float32 if compute_dtype is None else compute_dtype
    logits = (hn.astype(dt) @ lm_head.astype(dt)).astype(jnp.float32)
    vals, ids = jax.lax.top_k(logits, k)
    return ids.astype(jnp.int32), vals
