"""Pure-jnp oracle: SSD intra-chunk (diagonal-block) term (Mamba2 SSD)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(xdt: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
                  Cc: jnp.ndarray) -> jnp.ndarray:
    """One chunk's causal decay-attention.

    xdt: (B, c, nh, hd) — dt-weighted inputs
    cum: (B, c, nh)     — inclusive cumsum of A·dt
    Bc:  (B, c, ds); Cc: (B, c, ds) — input/output matrices (head-shared)
    Returns y_diag: (B, c, nh, hd) fp32:
        y[t] = Σ_{s≤t} (C_t·B_s) · exp(cum[t]−cum[s]) · xdt[s]
    """
    c = xdt.shape[1]
    rel = cum[:, :, None, :] - cum[:, None, :, :]            # (B,c,c,nh)
    causal = jnp.tril(jnp.ones((c, c), bool))
    M = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
    CB = jnp.einsum("bqd,bsd->bqs", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    W = CB[..., None] * M                                    # (B,c,c,nh)
    return jnp.einsum("bqsh,bshp->bqhp", W, xdt.astype(jnp.float32))
