"""Pallas TPU kernel: Mamba2 SSD intra-chunk term.

The SSD "diagonal block" is decay-masked attention: scores = (C·Bᵀ) ⊙
exp(cum_t − cum_s) under a causal mask, applied to dt-weighted inputs. The
C·Bᵀ Gram matrix is head-INDEPENDENT (single B/C group in mamba2-130m), so
the kernel computes it once per (batch, chunk) grid cell and sweeps heads in
the innermost grid dim, reusing the (c × c) score skeleton from VMEM — the
TPU-shaped equivalent of mamba2's fused CUDA chunk kernel.

Grid: (B·nc, nh). Per cell: Cc,Bc (c, ds) + cum (c, 1) + xdt (c, hd) tiles.
c = 64, ds = 128, hd = 64 ⇒ ~200 KB VMEM — trivially resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(c_ref, b_ref, cum_ref, x_ref, o_ref, *, chunk: int):
    Cc = c_ref[0].astype(jnp.float32)                  # (c, ds)
    Bc = b_ref[0].astype(jnp.float32)                  # (c, ds)
    cum = cum_ref[0, 0].astype(jnp.float32)            # (c, 1)
    x = x_ref[0, 0].astype(jnp.float32)                # (c, hd)
    cb = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)   # (c, c)
    rel = cum - cum.T                                  # (c, c): cum_t - cum_s
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(kpos <= qpos, cb * jnp.exp(rel), 0.0)
    o_ref[0, 0] = jnp.dot(w, x, preferred_element_type=jnp.float32)


def ssd_chunk_fwd(xdt: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
                  Cc: jnp.ndarray) -> jnp.ndarray:
    """xdt: (B, c, nh, hd); cum: (B, c, nh); Bc/Cc: (B, c, ds).
    Returns y_diag (B, c, nh, hd) fp32."""
    B, c, nh, hd = xdt.shape
    ds = Bc.shape[-1]
    # (B, c, nh, hd) -> (B, nh, c, hd) blocks keyed by (b, h)
    xt = jnp.moveaxis(xdt, 2, 1)                       # (B, nh, c, hd)
    cumt = jnp.moveaxis(cum, 2, 1)[..., None]          # (B, nh, c, 1)

    from repro.kernels import interpret_default, tpu_compiler_params
    fn = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=(B, nh),
        in_specs=[
            pl.BlockSpec((1, c, ds), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, c, ds), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, c, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, c, hd), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret_default(),
        name="ssd_chunk_diag",
    )
    out = fn(Cc, Bc, cumt, xt)                         # (B, nh, c, hd)
    return jnp.moveaxis(out, 1, 2)
