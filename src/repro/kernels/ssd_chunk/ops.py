"""Public jit'd wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_fwd


@jax.jit
def ssd_chunk(xdt, cum, Bc, Cc):
    return ssd_chunk_fwd(xdt, cum, Bc, Cc)
