"""Public jit'd wrapper for blocked flash attention."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k)
