"""Pallas TPU kernel: blocked flash attention (prefill path).

Online-softmax formulation: grid (B, H, nQ, nK) with the KV dimension
innermost ("arbitrary" semantics); running max / denominator / weighted
accumulator live in VMEM scratch carried across KV steps. Causality is
exploited twice:
  * whole KV blocks strictly above the diagonal are skipped via ``pl.when``
    (no MXU work, no VMEM traffic) — the scheduler still iterates the grid
    but the body is predicated off;
  * the diagonal block applies the elementwise triangular mask.
GQA maps query head h to KV head h // (H // KVH) inside the BlockSpec
index_map — KV blocks are fetched once per group, not per query head.
Sliding-window (local) attention masks out-of-window keys and skips blocks
entirely below the window.

VMEM budget per step: q (Bq×hd) + k,v (Bk×hd each) + scratch (Bq×hd + 2·Bq)
fp32 ≈ 4·128·128·4 B ≈ 256 KB at the default 128/128 tiling — comfortably
inside the ~16 MB v5e VMEM with double buffering.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: Optional[int], nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                 # (Bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (Bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                 # (Bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            mask = kpos <= qpos
            if window is not None:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                                 # (Bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # block-level skip: the block is live iff some (q, k) pair with
        # k <= q (and q - k < window) exists — dead blocks cost nothing
        live = k_start <= q_start + block_q - 1
        if window is not None:
            live = live & (k_start + block_k - 1 > q_start - window)
        pl.when(live)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    n_rep = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    while S % block_q:
        block_q //= 2
    while S % block_k:
        block_k //= 2
    nq, nk = S // block_q, S // block_k

    # layout: (B, H, S, hd) blocks of (1, 1, block, hd)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    from repro.kernels import interpret_default, tpu_compiler_params
    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, window=window,
                               nk=nk)
    fn = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // n_rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret_default(),
        name="specee_flash_attention",
    )
    out = fn(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
