"""Pure-jnp oracle for blocked flash attention (causal / windowed / full)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) — GQA repeated internally.
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    n_rep = H // KVH
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
