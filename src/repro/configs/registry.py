"""Config registry: lazy import of one module per architecture."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config import RunConfig

# arch id -> module name under repro.configs
ARCHS: List[str] = [
    # assigned pool (10)
    "dbrx-132b",
    "qwen3-moe-235b-a22b",
    "deepseek-7b",
    "minicpm-2b",
    "command-r-plus-104b",
    "starcoder2-15b",
    "internvl2-26b",
    "hubert-xlarge",
    "recurrentgemma-9b",
    "mamba2-130m",
    # paper's own models (for benchmarks vs. the paper's tables)
    "llama2-7b",
    "llama2-13b",
    "llama2-70b",
]

_REGISTRY: Dict[str, Callable[[], RunConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], RunConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def _module_for(name: str) -> str:
    return "repro.configs." + name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> RunConfig:
    if name not in _REGISTRY:
        importlib.import_module(_module_for(name))
    if name not in _REGISTRY:
        raise KeyError(f"config module for {name!r} did not register itself")
    return _REGISTRY[name]()
