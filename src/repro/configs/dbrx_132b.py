"""DBRX-132B — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.config import (FAMILY_MOE, MoEConfig, ModelConfig, RunConfig,
                          ShardingConfig)
from repro.configs.registry import register


@register("dbrx-132b")
def config() -> RunConfig:
    model = ModelConfig(
        name="dbrx-132b",
        family=FAMILY_MOE,
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        moe=MoEConfig(num_experts=16, num_experts_per_tok=4, expert_d_ff=10752),
        norm="layernorm",
        activation="silu",
        rope_theta=500000.0,
    )
    # 132B total -> weights must shard 2-D to fit v5e HBM; experts use EP over data
    return RunConfig(model=model, sharding=ShardingConfig(policy="tp2d"))
