"""RecurrentGemma-9B — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.

[arXiv:2402.19427; unverified]
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
Block pattern repeats (rglru, rglru, local_attention); 38 = 12*3 + 2 extra rglru.
Sub-quadratic -> runs long_500k.
"""
from repro.config import (FAMILY_HYBRID, LOCAL_ATTN, RGLRU, RGLRUConfig,
                          ModelConfig, RunConfig)
from repro.configs.registry import register


def _pattern(n: int):
    pat = []
    i = 0
    while len(pat) < n:
        pat.append(RGLRU)
        if len(pat) < n:
            pat.append(RGLRU)
        if len(pat) < n:
            pat.append(LOCAL_ATTN)
    return tuple(pat[:n])


@register("recurrentgemma-9b")
def config() -> RunConfig:
    model = ModelConfig(
        name="recurrentgemma-9b",
        family=FAMILY_HYBRID,
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        head_dim=256,
        block_pattern=_pattern(38),
        rglru=RGLRUConfig(lru_width=4096, conv_kernel=4, window=2048),
        norm="rmsnorm",
        activation="gelu",
    )
    return RunConfig(model=model)
