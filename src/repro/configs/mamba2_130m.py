"""Mamba2-130M — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]
24L d_model=768 vocab=50280, ssm_state=128, expand=2, head_dim=64.
Attention-free -> runs long_500k.
"""
from repro.config import FAMILY_SSM, ModelConfig, RunConfig, SSMConfig
from repro.configs.registry import register


@register("mamba2-130m")
def config() -> RunConfig:
    model = ModelConfig(
        name="mamba2-130m",
        family=FAMILY_SSM,
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_kernel=4,
                      chunk_size=64),
        tie_embeddings=True,
        norm="rmsnorm",
    )
    return RunConfig(model=model)
