"""Command R+ 104B — dense GQA, no bias.

[hf:CohereForAI/c4ai-command-r-v01; unverified]
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig, ShardingConfig
from repro.configs.registry import register


@register("command-r-plus-104b")
def config() -> RunConfig:
    model = ModelConfig(
        name="command-r-plus-104b",
        family=FAMILY_DENSE,
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        use_bias=False,
        norm="layernorm",
        activation="silu",
        rope_theta=75000000.0,
    )
    # 104B bf16 = 208 GB: must 2-D shard weights on a 16x16 v5e pod
    return RunConfig(model=model, sharding=ShardingConfig(policy="tp2d"))
