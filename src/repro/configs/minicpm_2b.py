"""MiniCPM-2B — llama-like dense, WSD learning-rate schedule.

[arXiv:2404.06395; hf]
40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753. Tied embeddings.
"""
from dataclasses import replace

from repro.config import FAMILY_DENSE, ModelConfig, RunConfig, TrainConfig
from repro.configs.registry import register


@register("minicpm-2b")
def config() -> RunConfig:
    model = ModelConfig(
        name="minicpm-2b",
        family=FAMILY_DENSE,
        num_layers=40,
        d_model=2304,
        num_heads=36,
        num_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        tie_embeddings=True,
        norm="rmsnorm",
        activation="silu",
    )
    # MiniCPM's signature Warmup-Stable-Decay schedule
    train = TrainConfig(schedule="wsd", learning_rate=1e-2 * (256 / 2304))
    return RunConfig(model=model, train=train)
