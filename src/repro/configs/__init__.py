"""Architecture configs. ``get_config(name)`` returns a RunConfig.

Assigned archs (10) + the paper's own Llama2 family (3).
"""
from repro.configs.registry import ARCHS, get_config, register

__all__ = ["ARCHS", "get_config", "register"]
