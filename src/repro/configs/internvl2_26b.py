"""InternVL2-26B — InternViT frontend (stub) + InternLM2-20B decoder backbone.

[arXiv:2404.16821; hf]
Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings of shape (batch, frontend_tokens, d_model) prepended to the text.
"""
from repro.config import FAMILY_VLM, ModelConfig, RunConfig, ShardingConfig
from repro.configs.registry import register


@register("internvl2-26b")
def config() -> RunConfig:
    model = ModelConfig(
        name="internvl2-26b",
        family=FAMILY_VLM,
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision_patches",
        frontend_tokens=256,
        norm="rmsnorm",
        activation="silu",
    )
    return RunConfig(model=model, sharding=ShardingConfig(policy="tp2d"))
