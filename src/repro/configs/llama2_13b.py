"""Llama2-13B (paper Table 3): 40L d_model=5120 40H d_ff=13824 vocab=32000."""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig
from repro.configs.registry import register


@register("llama2-13b")
def config() -> RunConfig:
    model = ModelConfig(
        name="llama2-13b",
        family=FAMILY_DENSE,
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=13824,
        vocab_size=32000,
        norm="rmsnorm",
        activation="silu",
        max_seq_len=4096,
    )
    return RunConfig(model=model)
