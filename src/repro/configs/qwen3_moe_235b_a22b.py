"""Qwen3-MoE-235B-A22B — 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B; hf]
94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
d_ff=1536 is the per-expert (moe_intermediate) width.
"""
from repro.config import (FAMILY_MOE, MoEConfig, ModelConfig, RunConfig,
                          ShardingConfig)
from repro.configs.registry import register


@register("qwen3-moe-235b-a22b")
def config() -> RunConfig:
    model = ModelConfig(
        name="qwen3-moe-235b-a22b",
        family=FAMILY_MOE,
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=1536,
        vocab_size=151936,
        head_dim=64,
        moe=MoEConfig(num_experts=128, num_experts_per_tok=8, expert_d_ff=1536),
        norm="rmsnorm",
        activation="silu",
        rope_theta=1000000.0,
    )
    return RunConfig(model=model, sharding=ShardingConfig(policy="tp2d"))
