"""StarCoder2-15B — GQA + RoPE, layernorm + bias.

[arXiv:2402.19173; hf]
40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig
from repro.configs.registry import register


@register("starcoder2-15b")
def config() -> RunConfig:
    model = ModelConfig(
        name="starcoder2-15b",
        family=FAMILY_DENSE,
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        use_bias=True,
        norm="layernorm",
        activation="gelu",
        gated_mlp=False,
        rope_theta=100000.0,
    )
    return RunConfig(model=model)
