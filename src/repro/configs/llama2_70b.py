"""Llama2-70B (paper Table 3): 80L d_model=8192 64H (GQA kv=8) d_ff=28672."""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig, ShardingConfig
from repro.configs.registry import register


@register("llama2-70b")
def config() -> RunConfig:
    model = ModelConfig(
        name="llama2-70b",
        family=FAMILY_DENSE,
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=32000,
        norm="rmsnorm",
        activation="silu",
        max_seq_len=4096,
    )
    return RunConfig(model=model, sharding=ShardingConfig(policy="tp2d"))
