"""Llama2-7B — the paper's primary evaluation model (Table 3).

32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000, 4k context.
"""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig
from repro.configs.registry import register


@register("llama2-7b")
def config() -> RunConfig:
    model = ModelConfig(
        name="llama2-7b",
        family=FAMILY_DENSE,
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        norm="rmsnorm",
        activation="silu",
        max_seq_len=4096,
    )
    return RunConfig(model=model)
