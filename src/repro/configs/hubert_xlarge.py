"""HuBERT-XLarge — encoder-only audio transformer (masked unit prediction).

[arXiv:2106.07447; unverified]
48L d_model=1280 16H (kv=16, MHA) d_ff=5120 vocab=504 (cluster-unit codebook).
Encoder-only: non-causal attention; no decode shapes; SpecEE inapplicable
(no autoregressive LM-head search) — see DESIGN.md §4.
The conv waveform frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (batch, seq, d_model).
"""
from repro.config import FAMILY_AUDIO, ModelConfig, RunConfig, SpecEEConfig
from repro.configs.registry import register


@register("hubert-xlarge")
def config() -> RunConfig:
    model = ModelConfig(
        name="hubert-xlarge",
        family=FAMILY_AUDIO,
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        use_bias=True,
        norm="layernorm",
        activation="gelu",
        gated_mlp=False,
        frontend="audio_frames",
        frontend_tokens=0,   # frames ARE the sequence; nothing prepended
    )
    return RunConfig(model=model, specee=SpecEEConfig(enabled=False))
