"""DeepSeek-7B — llama-arch dense MHA.

[arXiv:2401.02954; hf]
30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from repro.config import FAMILY_DENSE, ModelConfig, RunConfig
from repro.configs.registry import register


@register("deepseek-7b")
def config() -> RunConfig:
    model = ModelConfig(
        name="deepseek-7b",
        family=FAMILY_DENSE,
        num_layers=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=11008,
        vocab_size=102400,
        norm="rmsnorm",
        activation="silu",
    )
    return RunConfig(model=model)
