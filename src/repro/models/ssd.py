"""Mamba2 — SSD (state-space duality) block. [arXiv:2405.21060]

Sequence path uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk linear state recurrence via ``lax.scan`` over chunks); decode path
is the O(1) recurrent update. A Pallas kernel for the intra-chunk term lives in
``repro.kernels.ssd_chunk`` (optional drop-in).

Layout conventions (single B/C group, as in mamba2-130m):
  x  : (B, S, nh, hd)      — inner activations split into SSM heads
  dt : (B, S, nh)          — per-head timestep (softplus(dt + bias))
  A  : (nh,)               — negative decay rate (−exp(A_log))
  Bm : (B, S, ds)          — input matrix  (shared across heads)
  Cm : (B, S, ds)          — output matrix (shared across heads)
  state: (B, nh, hd, ds)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.models import common
from repro.models.common import KeyGen, Params


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm or SSMConfig()
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return di, nh, s.head_dim, s.d_state


def init_ssd(cfg: ModelConfig, kg: KeyGen) -> Params:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    di, nh, hd, ds = dims(cfg)
    conv_ch = di + 2 * ds
    # in_proj emits [z (di), x (di), B (ds), C (ds), dt (nh)]
    out_dim = 2 * di + 2 * ds + nh
    p: Params = {
        "in_proj": {"w": common.normal_init(kg(), (d, out_dim), 1.0 / math.sqrt(d))},
        "conv_w": common.normal_init(kg(), (s.conv_kernel, conv_ch),
                                     1.0 / math.sqrt(s.conv_kernel)),
        "conv_b": common.zeros_init((conv_ch,)),
        # A in [-1, -e]: A_log ~ log(Uniform[1, 16])
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": common.ones_init((nh,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                kg(), (nh,), minval=math.log(1e-3), maxval=math.log(1e-1))),
                1e-4, None))),
        "norm": {"scale": common.ones_init((di,))},
        "out_proj": {"w": common.normal_init(
            kg(), (di, d), 1.0 / math.sqrt(di) / math.sqrt(2 * cfg.num_layers))},
    }
    return p


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    di, nh, hd, ds = dims(cfg)
    z, xBC_dt = jnp.split(proj, [di], axis=-1)
    xBC, dt = jnp.split(xBC_dt, [di + 2 * ds], axis=-1)
    return z, xBC, dt  # (…, di), (…, di+2ds), (…, nh)


def _gated_rmsnorm(p: Params, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Mamba2 out-norm: RMSNorm(x * silu(z))."""
    y = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + 1e-6) *
            p["norm"]["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked SSD over a sequence
# ---------------------------------------------------------------------------
def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                initial_state: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds)).

    Discretization: a_t = exp(A * dt_t); input contribution dt_t * x_t ⊗ B_t.
    y_t = C_t · h_t (+ no D here; D is added by the caller).
    """
    B, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    S_orig = S
    if S % chunk != 0:
        # pad with dt=0 tokens: a=exp(A*0)=1 and input dt*x=0, so padding is a
        # no-op on the state; padded outputs are sliced off below.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk

    xc = x.reshape(B, nc, chunk, nh, hd)
    dtc = dt.reshape(B, nc, chunk, nh)
    Bc = Bm.reshape(B, nc, chunk, ds)
    Cc = Cm.reshape(B, nc, chunk, ds)

    # log decay within chunk: L[t] = cumsum of A*dt up to t (inclusive)
    ladt = A[None, None, None, :] * dtc                       # (B,nc,Q,nh)
    cum = jnp.cumsum(ladt, axis=2)                            # inclusive
    # intra-chunk ("diagonal block") term: attention-like with decay kernel
    # M[t, s] = exp(cum[t] - cum[s]) for s <= t  (decay from s+1..t)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,Q,Q,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    # scores: C_t · B_s (shared across heads)
    CB = jnp.einsum("bcqd,bcsd->bcqs", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    W = CB[..., None] * M                                      # (B,nc,Q,Q,nh)
    xdt = xc.astype(jnp.float32) * dtc[..., None]              # (B,nc,Q,nh,hd)
    y_diag = jnp.einsum("bcqsh,bcshp->bcqhp", W, xdt)

    # chunk-level states: contribution of chunk c to the state after chunk c
    # decay from position s to end of chunk: exp(cum[-1] - cum[s])
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (B,nc,Q,nh)
    states = jnp.einsum("bcsd,bcsh,bcshp->bchpd",
                        Bc.astype(jnp.float32), dec_to_end, xdt)  # (B,nc,nh,hd,ds)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,nc,nh)

    # inter-chunk recurrence over nc (scan)
    h0 = (jnp.zeros((B, nh, hd, ds), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, inp):
        st, dec = inp                                          # (B,nh,hd,ds),(B,nh)
        h_out = h                                              # state BEFORE chunk
        h_next = h * dec[:, :, None, None] + st
        return h_next, h_out

    states_t = jnp.moveaxis(states, 1, 0)                      # (nc,B,nh,hd,ds)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,B,nh)
    h_final, h_before = jax.lax.scan(step, h0, (states_t, decay_t))
    h_before = jnp.moveaxis(h_before, 0, 1)                    # (B,nc,nh,hd,ds)

    # inter-chunk ("off-diagonal") output: y += C_t · (decay(0..t) * h_before)
    dec_from_start = jnp.exp(cum)                              # (B,nc,Q,nh)
    y_off = jnp.einsum("bcqd,bchpd,bcqh->bcqhp",
                       Cc.astype(jnp.float32), h_before, dec_from_start)

    y = (y_diag + y_off).reshape(B, S, nh, hd)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssd_recurrent_step(x, dt, A, Bm, Cm, state):
    """Single-token update. x: (B,nh,hd); dt: (B,nh); Bm,Cm: (B,ds);
    state: (B,nh,hd,ds) -> (y (B,nh,hd), new_state)."""
    a = jnp.exp(A[None, :] * dt)                               # (B,nh)
    xdt = x.astype(jnp.float32) * dt[..., None]                # (B,nh,hd)
    new_state = (state.astype(jnp.float32) * a[:, :, None, None]
                 + xdt[..., None] * Bm[:, None, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpd,bd->bhp", new_state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# full block (norm -> in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------
def conv1d_seq(w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over sequence. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def conv1d_step(w: jnp.ndarray, b: jnp.ndarray, x_t: jnp.ndarray,
                conv_state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x_t: (B, C); conv_state: (B, K-1, C) holding the previous K-1 inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b[None, :]
    new_state = window[:, 1:, :]
    return out, new_state


def ssd_block_seq(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  initial_state=None, conv_carry=None):
    """Full-sequence SSD block (train/prefill). x: (B,S,D) (pre-normed outside)."""
    s = cfg.ssm or SSMConfig()
    di, nh, hd, ds = dims(cfg)
    proj = common.apply_linear(p["in_proj"], x)                # (B,S,2di+2ds+nh)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC = jax.nn.silu(conv1d_seq(p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), xBC))
    xin, Bm, Cm = jnp.split(xBC, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])          # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                   # (nh,)
    xh = xin.reshape(*xin.shape[:-1], nh, hd)
    y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk_size, initial_state)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:-1], di)
    y = _gated_rmsnorm(p, y, z)
    out = common.apply_linear(p["out_proj"], y)
    # conv carry for seamless decode continuation
    K = (cfg.ssm or SSMConfig()).conv_kernel
    proj_tail = proj[:, -(K - 1):, di:di + di + 2 * ds] if x.shape[1] >= K - 1 else None
    return out, h_final, proj_tail


def ssd_block_step(cfg: ModelConfig, p: Params, x_t: jnp.ndarray,
                   state: jnp.ndarray, conv_state: jnp.ndarray):
    """Single-token SSD block. x_t: (B, D) pre-normed; returns (out (B,D),
    new_state, new_conv_state)."""
    di, nh, hd, ds = dims(cfg)
    proj = common.apply_linear(p["in_proj"], x_t)              # (B, 2di+2ds+nh)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, new_conv = conv1d_step(p["conv_w"].astype(x_t.dtype),
                                p["conv_b"].astype(x_t.dtype), xBC, conv_state)
    xBC = jax.nn.silu(xBC)
    xin, Bm, Cm = jnp.split(xBC, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(-1, nh, hd)
    y, new_state = ssd_recurrent_step(xh, dt, A, Bm, Cm, state)
    y = y + xh * p["D"].astype(x_t.dtype)[None, :, None]
    y = y.reshape(-1, di)
    y = _gated_rmsnorm(p, y, z)
    return common.apply_linear(p["out_proj"], y), new_state, new_conv
