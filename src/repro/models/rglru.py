"""RG-LRU recurrent block (RecurrentGemma / Griffin). [arXiv:2402.19427]

Block: ln -> {gate branch: Linear+GeLU} x {x branch: Linear -> causal conv ->
RG-LRU} -> out proj. The RG-LRU recurrence:

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    log a_t = -c * softplus(Λ) * r_t        (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Sequence path uses ``jax.lax.associative_scan`` over the linear recurrence
(h_t = a_t h_{t-1} + b_t) — the TPU-idiomatic log-depth formulation; decode
path is the O(1) update.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RGLRUConfig
from repro.models import common
from repro.models.common import KeyGen, Params

_C = 8.0


def lru_width(cfg: ModelConfig) -> int:
    r = cfg.rglru or RGLRUConfig()
    return r.lru_width or cfg.d_model


def init_rglru(cfg: ModelConfig, kg: KeyGen) -> Params:
    r = cfg.rglru or RGLRUConfig()
    d, w = cfg.d_model, lru_width(cfg)
    std_d = 1.0 / math.sqrt(d)
    std_w = 1.0 / math.sqrt(w)
    out_std = std_w / math.sqrt(2 * cfg.num_layers)
    # Λ init so that a^c ∈ ~(0.9, 0.999)
    u = jax.random.uniform(kg(), (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))  # softplus^-1
    return {
        "wx": {"w": common.normal_init(kg(), (d, w), std_d)},      # x branch
        "wy": {"w": common.normal_init(kg(), (d, w), std_d)},      # gate branch
        "conv_w": common.normal_init(kg(), (r.conv_kernel, w),
                                     1.0 / math.sqrt(r.conv_kernel)),
        "conv_b": common.zeros_init((w,)),
        "wa": {"w": common.normal_init(kg(), (w, w), std_w),
               "b": common.zeros_init((w,))},
        "wi": {"w": common.normal_init(kg(), (w, w), std_w),
               "b": common.zeros_init((w,))},
        "lam": lam,
        "wo": {"w": common.normal_init(kg(), (w, d), out_std)},
    }


def _gates(p: Params, x: jnp.ndarray):
    """x: (..., W) post-conv activations -> (log_a, b_t) of the recurrence."""
    r = jax.nn.sigmoid(common.apply_linear(p["wa"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(common.apply_linear(p["wi"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2 * log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a2, 1e-12, None)) * i * x.astype(jnp.float32)
    return log_a, b


def rglru_scan(p: Params, x: jnp.ndarray,
               h0: jnp.ndarray | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, W) -> (h (B,S,W), h_final (B,W)) via associative scan."""
    log_a, b = _gates(p, x)                                    # (B,S,W) fp32
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold the initial state into the first input
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(x.dtype), hh[:, -1, :]


def rglru_step(p: Params, x_t: jnp.ndarray,
               h: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x_t: (B, W); h: (B, W) -> (out, new_h)."""
    log_a, b = _gates(p, x_t)
    new_h = jnp.exp(log_a) * h.astype(jnp.float32) + b
    return new_h.astype(x_t.dtype), new_h


def rglru_block_seq(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                    h0=None, conv_carry_in=None):
    """Full recurrent block over a sequence. x: (B,S,D) pre-normed.
    Returns (out (B,S,D), h_final (B,W), conv_tail (B,K-1,W))."""
    r = cfg.rglru or RGLRUConfig()
    gate = jax.nn.gelu(common.apply_linear(p["wy"], x))
    xb = common.apply_linear(p["wx"], x)
    xc = _conv_seq(p, xb, conv_carry_in)
    h_seq, h_final = rglru_scan(p, xc, h0)
    out = common.apply_linear(p["wo"], h_seq * gate)
    K = r.conv_kernel
    conv_tail = xb[:, -(K - 1):, :] if xb.shape[1] >= K - 1 else None
    return out, h_final, conv_tail


def _conv_seq(p: Params, xb: jnp.ndarray, carry=None) -> jnp.ndarray:
    K = p["conv_w"].shape[0]
    if carry is None:
        pad = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([carry.astype(xb.dtype), xb], axis=1)
    w = p["conv_w"].astype(xb.dtype)
    out = sum(pad[:, i:i + xb.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + p["conv_b"].astype(xb.dtype)[None, None, :]


def rglru_block_step(cfg: ModelConfig, p: Params, x_t: jnp.ndarray,
                     h: jnp.ndarray, conv_state: jnp.ndarray):
    """Single-token recurrent block. x_t: (B,D) pre-normed.
    conv_state: (B, K-1, W). Returns (out (B,D), new_h, new_conv_state)."""
    gate = jax.nn.gelu(common.apply_linear(p["wy"], x_t))
    xb = common.apply_linear(p["wx"], x_t)                     # (B, W)
    window = jnp.concatenate([conv_state.astype(xb.dtype), xb[:, None, :]], axis=1)
    xc = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(xb.dtype)) \
        + p["conv_b"].astype(xb.dtype)[None, :]
    h_out, new_h = rglru_step(p, xc, h)
    out = common.apply_linear(p["wo"], h_out * gate)
    return out, new_h, window[:, 1:, :]
