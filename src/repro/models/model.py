"""Unified model API over the zoo.

A model's layer stack is decomposed into **segments**: maximal runs of a
repeating *unit* of block kinds, e.g.

    llama2-7b          -> [ ((attention,), 32) ]
    mamba2-130m        -> [ ((ssd,), 24) ]
    recurrentgemma-9b  -> [ ((rglru, rglru, local_attention), 12), ((rglru,), 2) ]

Parameters for each segment are *stacked* over the repeat count, so the
sequence path runs as ``lax.scan`` over units (fast compile at any depth) and
the early-exit decode path runs as ``lax.while_loop`` with
``dynamic_index_in_dim`` into the same stacks. SpecEE exit points sit at unit
boundaries (DESIGN.md §3: exit granularity = unit = 1 layer for homogeneous
archs, 3 layers for the hybrid).

Public surface (all functions are pure; ``Model`` just binds the config):
    m = build_model(run_config)
    params = m.init(key)
    loss, aux = m.train_loss(params, batch, rng)
    logits, cache = m.prefill(params, batch)
    logits, cache = m.decode_step(params, token, cache)          # dense baseline
    h, cache = m.run_unit(params, seg, unit_idx, h, cache, pos)  # SpecEE engine API
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ATTN, LOCAL_ATTN, RGLRU, SSD, ModelConfig, RunConfig,
                          SSMConfig)
from repro.core import paged as paged_lib
from repro.models import attention as attn_lib
from repro.models import common, frontends, moe as moe_lib, rglru as rglru_lib
from repro.models import ssd as ssd_lib
from repro.models.common import KeyGen, Params


# ---------------------------------------------------------------------------
# segment decomposition
# ---------------------------------------------------------------------------
def segments_of(blocks: Sequence[str], max_unit: int = 4
                ) -> List[Tuple[Tuple[str, ...], int]]:
    """Greedy decomposition of a block pattern into (unit, repeat) segments."""
    blocks = list(blocks)
    segs: List[Tuple[Tuple[str, ...], int]] = []
    i, n = 0, len(blocks)
    while i < n:
        best_unit, best_cov = (blocks[i],), 1
        for ul in range(1, max_unit + 1):
            if i + ul > n:
                break
            unit = blocks[i:i + ul]
            reps = 1
            while (i + (reps + 1) * ul <= n and
                   blocks[i + reps * ul: i + (reps + 1) * ul] == unit):
                reps += 1
            cov = reps * ul
            if cov > best_cov:
                best_unit, best_cov = tuple(unit), cov
        segs.append((best_unit, best_cov // len(best_unit)))
        i += best_cov
    return segs


@dataclass(frozen=True)
class ModelFlags:
    """Implementation-selection knobs (kernels, MoE formulation, remat)."""
    moe_impl: str = "dense"        # "dense" (EP-shardable einsum) | "topk" (gather)
    flash_attention: bool = False  # Pallas prefill kernel
    decode_kernel: bool = False    # Pallas split-KV decode kernel
    spec_head_kernel: bool = False  # Pallas fused speculative-LM-head kernel
    exit_gate_kernel: bool = False  # fused exit-gate pipeline (§Perf): the
    #   per-exit-point spec-head→predictor→verify chain runs through
    #   repro.kernels.exit_gate instead of the four-op reference sequence;
    #   verification streams the LM head (never materializes (B, V) logits)
    exit_gate_impl: str = "auto"   # fused backend: "auto" (kernel on TPU,
    #   fused-XLA elsewhere) | "kernel" | "xla" — only read when
    #   exit_gate_kernel is True
    remat: str = "none"            # "none" | "full"
    chunk_threshold: int = 2048    # chunked exact attention above this seq len
    chunk_size: int = 512          # query-chunk size for chunked attention
    ce_chunk: int = 512            # sequence-chunk size for the chunked CE loss
    kv_quant: bool = False         # int8 KV cache (per-vector scales) — §Perf
    #   beyond-paper optimization: halves decode's dominant HBM term
    attn_prune: bool = False       # causally-pruned chunked attention (§Perf):
    #   dynamic KV bounds recover the 2× causal FLOP saving in prefill/train
    moe_ep_quant: bool = False     # int8 EP token dispatch (§Perf): halves
    #   the MoE all-gather bytes on the ICI
    moe_bf16_reduce: bool = False  # bf16 accumulation for the MoE combine
    #   einsum (§Perf): the cross-device partial-sum reduction moves bf16
    #   instead of f32 — halves the dominant EP psum bytes
    act_seq_shard: bool = False    # Megatron sequence parallelism (§Perf):
    #   pin the residual stream's seq dim over 'model' at unit boundaries —
    #   row-parallel psums become reduce-scatters (half the AR payload)
    act_pin_full: bool = False     # pin the residual to P(batch, None, None)
    #   exactly (§Perf): stops GSPMD bouncing h between shardings across the
    #   layer body (kills the per-layer AG/AR resharding pairs)
    matmul_bf16_reduce: bool = False  # row-parallel projections emit bf16
    #   (§Perf): cross-shard psums move 2 bytes/elem instead of XLA's f32
    unroll: bool = False           # python-loop layers instead of lax.scan —
    #   identical math; used by roofline lowering so XLA cost_analysis counts
    #   every layer (scan bodies are counted once)
    # activation sharding constraints (MaxText-style): mesh axis name(s) for
    # the batch dim of the residual stream, pinned at every unit boundary so
    # GSPMD never "helpfully" replicates the batch. None = no constraints
    # (single-device tests). Example: ("pod", "data") or "data".
    act_batch_axes: Any = None
    act_batch_extent: int = 1      # product of those axes' sizes (skip the
    #   constraint when the batch dim does not divide it, e.g. long_500k B=1)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, kind: str, kg: KeyGen) -> Params:
    if kind in (ATTN, LOCAL_ATTN):
        p: Params = {"ln1": common.init_norm(cfg, cfg.d_model),
                     "attn": attn_lib.init_attention(cfg, kg),
                     "ln2": common.init_norm(cfg, cfg.d_model)}
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe(cfg, kg)
        else:
            p["mlp"] = common.init_mlp(cfg, kg)
        return p
    if kind == RGLRU:
        return {"ln1": common.init_norm(cfg, cfg.d_model),
                "rec": rglru_lib.init_rglru(cfg, kg),
                "ln2": common.init_norm(cfg, cfg.d_model),
                "mlp": common.init_mlp(cfg, kg)}
    if kind == SSD:
        return {"ln": common.init_norm(cfg, cfg.d_model),
                "ssd": ssd_lib.init_ssd(cfg, kg)}
    raise ValueError(kind)


def _window(cfg: ModelConfig, kind: str) -> Optional[int]:
    if kind == LOCAL_ATTN:
        return (cfg.rglru.window if cfg.rglru else 2048)
    return None


def _kv_quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(position, head) symmetric int8: x (..., hd) -> (q int8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def _entry_write_token(cache_entry: Any, vals: Dict[str, jnp.ndarray],
                       pages: Optional[jnp.ndarray], rows: jnp.ndarray,
                       pvec: jnp.ndarray) -> Any:
    """Write one token's projections into an attention cache entry.

    vals: {"k": ..., "v": ...} (+"ks"/"vs" under kv_quant), each (B, ...).
    The ONE place the dense row-scatter vs paged table-scatter choice is
    made for single-token writes — decode step and skipped-layer propagation
    share it, so the two paths cannot drift."""
    if pages is None:
        return {name: cache_entry[name].at[rows, pvec].set(
                    v.astype(cache_entry[name].dtype))
                for name, v in vals.items()}
    return {name: paged_lib.scatter_token(cache_entry[name], pages, pvec, v)
            for name, v in vals.items()}


def _wsc(x: jnp.ndarray, flags: "ModelFlags") -> jnp.ndarray:
    """Pin the batch dim of an activation to the data axes (and, under
    ``act_seq_shard``, the sequence dim to 'model'); leave every other dim to
    GSPMD (UNCONSTRAINED)."""
    if flags.act_batch_axes is None or x.ndim == 0:
        return x
    if flags.act_batch_extent and x.shape[0] % max(flags.act_batch_extent, 1):
        return x
    from jax.sharding import PartitionSpec as P
    if flags.act_pin_full and x.ndim >= 3:
        rest: list = [None] * (x.ndim - 1)
    else:
        rest = [P.UNCONSTRAINED] * (x.ndim - 1)
    if (flags.act_seq_shard and x.ndim >= 3 and
            x.shape[1] >= 1024 and x.shape[1] % 16 == 0):
        rest[0] = "model"
    return jax.lax.with_sharding_constraint(
        x, P(flags.act_batch_axes, *rest))


def _ffn(cfg: ModelConfig, p: Params, h: jnp.ndarray,
         flags: ModelFlags) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Post-attention FFN (dense MLP or MoE). Returns (out, aux_loss)."""
    if "moe" in p:
        if flags.moe_impl == "dense":
            return moe_lib.apply_moe(cfg, p["moe"], h,
                                     ep_axes=flags.act_batch_axes,
                                     ep_extent=flags.act_batch_extent,
                                     ep_quant=flags.moe_ep_quant,
                                     bf16_reduce=flags.moe_bf16_reduce)
        return moe_lib.apply_moe_topk(cfg, p["moe"], h)
    return common.apply_mlp(cfg, p["mlp"], h), jnp.float32(0.0)


# ----- sequence (train / prefill) path -------------------------------------
def _block_seq(cfg: ModelConfig, kind: str, p: Params, h: jnp.ndarray,
               positions: jnp.ndarray, flags: ModelFlags,
               collect_cache: bool) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (h_out, cache_entry_or_None, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in (ATTN, LOCAL_ATTN):
        x = common.apply_norm(cfg, p["ln1"], h)
        q, k, v = attn_lib.qkv(cfg, p["attn"], x, positions)
        if flags.flash_attention and cfg.causal:
            from repro.kernels.flash_attention import ops as fa_ops
            o = fa_ops.flash_attention(q, k, v, causal=True,
                                       window=_window(cfg, kind))
        elif x.shape[1] > flags.chunk_threshold:
            if flags.attn_prune and cfg.causal:
                o = attn_lib.attend_full_chunked_pruned(
                    cfg, q, k, v, _window(cfg, kind), chunk=flags.chunk_size)
            else:
                o = attn_lib.attend_full_chunked(cfg, q, k, v,
                                                 _window(cfg, kind),
                                                 chunk=flags.chunk_size)
        else:
            o = attn_lib.attend_full(cfg, q, k, v, _window(cfg, kind))
        pet = jnp.bfloat16 if flags.matmul_bf16_reduce else None
        h = h + attn_lib.out_proj(p["attn"], o, pet=pet)
        x2 = common.apply_norm(cfg, p["ln2"], h)
        if "moe" in p:
            f, aux = _ffn(cfg, p, x2, flags)
        else:
            f = common.apply_mlp(cfg, p["mlp"], x2, pet=pet)
        h = h + f
        cache = {"k": k, "v": v} if collect_cache else None
        return h, cache, aux
    if kind == RGLRU:
        x = common.apply_norm(cfg, p["ln1"], h)
        out, h_rec, conv_tail = rglru_lib.rglru_block_seq(cfg, p["rec"], x)
        h = h + out
        x2 = common.apply_norm(cfg, p["ln2"], h)
        h = h + common.apply_mlp(cfg, p["mlp"], x2)
        cache = ({"h": h_rec, "conv": conv_tail} if collect_cache else None)
        return h, cache, aux
    if kind == SSD:
        x = common.apply_norm(cfg, p["ln"], h)
        out, state, conv_tail = ssd_lib.ssd_block_seq(cfg, p["ssd"], x)
        h = h + out
        cache = ({"state": state, "conv": conv_tail} if collect_cache else None)
        return h, cache, aux
    raise ValueError(kind)


# ----- single-token decode path ---------------------------------------------
def _block_step(cfg: ModelConfig, kind: str, p: Params, h: jnp.ndarray,
                cache_entry: Any, pos: jnp.ndarray, flags: ModelFlags,
                live_mask: Optional[jnp.ndarray] = None,
                pages: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Any]:
    """h: (B, D) one token; cache_entry: this block's slice of the cache.
    pos: scalar int32 — index of the current token. Returns (h_out, new_entry).

    live_mask: (B,) bool — SpecEE: rows that have exited keep their recurrent
    state stale (attention K/V writes are propagation-consistent because the
    input hidden state of exited rows is frozen at the exit value).

    pages: (B, P) int32 page table or None. When set, attention cache leaves
    are page pools ``(n_pages, page_size, ...)`` and every read/write goes
    through the table (``repro.core.paged``); the gathered logical view keeps
    the math bit-identical to the dense layout. Recurrent/SSD entries are
    never paged."""
    B, D = h.shape
    if kind in (ATTN, LOCAL_ATTN):
        x = common.apply_norm(cfg, p["ln1"], h)[:, None, :]       # (B,1,D)
        pvec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        positions = pvec[:, None]
        rows = jnp.arange(B)
        q, k, v = attn_lib.qkv(cfg, p["attn"], x, positions)
        if flags.kv_quant:
            kq, ks = _kv_quantize(k[:, 0])
            vq, vs = _kv_quantize(v[:, 0])
            new_entry = _entry_write_token(
                cache_entry, {"k": kq, "v": vq, "ks": ks, "vs": vs},
                pages, rows, pvec)
        else:
            new_entry = _entry_write_token(
                cache_entry, {"k": k[:, 0], "v": v[:, 0]}, pages, rows, pvec)
        use_paged_kernel = flags.decode_kernel and pages is not None
        if use_paged_kernel:
            # the paged kernel reads pages (and, under kv_quant, scale
            # pages) straight from the pool — never build the gathered view
            k_cache = v_cache = None
        else:
            if pages is None:
                k_view, v_view = new_entry["k"], new_entry["v"]
                ks_view = new_entry.get("ks")
                vs_view = new_entry.get("vs")
            else:
                k_view = paged_lib.gather_view(new_entry["k"], pages)
                v_view = paged_lib.gather_view(new_entry["v"], pages)
                ks_view = (paged_lib.gather_view(new_entry["ks"], pages)
                           if flags.kv_quant else None)
                vs_view = (paged_lib.gather_view(new_entry["vs"], pages)
                           if flags.kv_quant else None)
            if flags.kv_quant:
                k_cache = _kv_dequantize(k_view, ks_view, h.dtype)
                v_cache = _kv_dequantize(v_view, vs_view, h.dtype)
            else:
                k_cache, v_cache = k_view, v_view
        if use_paged_kernel:
            # page-table-aware split-KV kernel: reads pages straight from the
            # pool, never materializing the (B, S, ...) logical view; int8
            # pools stream codes + per-position scale pages and dequantize
            # in-register (gather∘dequant ≡ dequant∘gather — per-position
            # scales commute with the page gather)
            from repro.kernels.decode_attention import ops as da_ops
            o = da_ops.paged_decode_attention(
                cfg, q, new_entry["k"], new_entry["v"], pages, pvec + 1,
                window=_window(cfg, kind),
                k_scale=new_entry["ks"] if flags.kv_quant else None,
                v_scale=new_entry["vs"] if flags.kv_quant else None)
        elif flags.decode_kernel:
            from repro.kernels.decode_attention import ops as da_ops
            o = da_ops.decode_attention(cfg, q, k_cache, v_cache, pvec + 1,
                                        window=_window(cfg, kind))
        else:
            o = attn_lib.attend_decode(cfg, q, k_cache, v_cache, pvec + 1,
                                       _window(cfg, kind))
        h = h + attn_lib.out_proj(p["attn"], o)[:, 0, :]
        x2 = common.apply_norm(cfg, p["ln2"], h[:, None, :])
        f, _ = _ffn(cfg, p, x2, flags)
        h = h + f[:, 0, :]
        return h, new_entry
    if kind == RGLRU:
        x = common.apply_norm(cfg, p["ln1"], h)
        out, new_h, new_conv = rglru_lib.rglru_block_step(
            cfg, p["rec"], x, cache_entry["h"], cache_entry["conv"])
        if live_mask is not None:
            new_h = jnp.where(live_mask[:, None], new_h, cache_entry["h"])
        h = h + out
        x2 = common.apply_norm(cfg, p["ln2"], h)
        h = h + common.apply_mlp(cfg, p["mlp"], x2)
        return h, {"h": new_h, "conv": new_conv}
    if kind == SSD:
        x = common.apply_norm(cfg, p["ln"], h)
        out, new_state, new_conv = ssd_lib.ssd_block_step(
            cfg, p["ssd"], x, cache_entry["state"], cache_entry["conv"])
        if live_mask is not None:
            new_state = jnp.where(live_mask[:, None, None, None], new_state,
                                  cache_entry["state"])
        h = h + out
        return h, {"state": new_state, "conv": new_conv}
    raise ValueError(kind)


def _block_propagate(cfg: ModelConfig, kind: str, p: Params, h: jnp.ndarray,
                     cache_entry: Any, pos: jnp.ndarray,
                     flags: ModelFlags = ModelFlags(),
                     pages: Optional[jnp.ndarray] = None) -> Any:
    """SpecEE skipped-layer state maintenance (DESIGN.md §3).

    Attention: KV propagation — write K/V projections of the *exit* hidden
    state so future tokens can attend to this position. Recurrent/SSM blocks:
    stale state (no update) is the correct analogue; conv states DO get the
    current input pushed so the temporal window stays aligned.
    """
    if kind in (ATTN, LOCAL_ATTN):
        B, D = h.shape
        x = common.apply_norm(cfg, p["ln1"], h)[:, None, :]
        pvec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        rows = jnp.arange(B)
        k, v = attn_lib.kv_only(cfg, p["attn"], x, pvec[:, None])
        if flags.kv_quant:
            kq, ks = _kv_quantize(k[:, 0])
            vq, vs = _kv_quantize(v[:, 0])
            vals = {"k": kq, "v": vq, "ks": ks, "vs": vs}
        else:
            vals = {"k": k[:, 0], "v": v[:, 0]}
        return _entry_write_token(cache_entry, vals, pages, rows, pvec)
    if kind == RGLRU:
        x = common.apply_norm(cfg, p["ln1"], h)
        xb = common.apply_linear(p["rec"]["wx"], x)
        window = jnp.concatenate(
            [cache_entry["conv"].astype(xb.dtype), xb[:, None, :]], axis=1)
        return {"h": cache_entry["h"], "conv": window[:, 1:, :]}
    if kind == SSD:
        x = common.apply_norm(cfg, p["ln"], h)
        proj = common.apply_linear(p["ssd"]["in_proj"], x)
        _, xBC, _ = ssd_lib._split_proj(cfg, proj)
        window = jnp.concatenate(
            [cache_entry["conv"].astype(xBC.dtype), xBC[:, None, :]], axis=1)
        return {"state": cache_entry["state"], "conv": window[:, 1:, :]}
    raise ValueError(kind)


# ----- chunked-prefill extension step ---------------------------------------
def _block_extend(cfg: ModelConfig, kind: str, p: Params, h: jnp.ndarray,
                  cache_entry: Any, pos0: jnp.ndarray,
                  positions: jnp.ndarray, flags: ModelFlags
                  ) -> Tuple[jnp.ndarray, Any]:
    """Process a C-token prompt chunk against a dense decode cache.

    h: (B, C, D); pos0: (B,) prefix length; positions: (B, C) absolute
    positions of the chunk. Chunk K/V is written (quantized under
    ``kv_quant``) before attending, so intra-chunk causal attention sees its
    own keys exactly like the decode step does. Attention-family blocks only
    (DESIGN.md §4 — chunked prefill needs an order-free state extension,
    which recurrent/SSD blocks don't expose)."""
    assert kind in (ATTN, LOCAL_ATTN)
    B, C, D = h.shape
    x = common.apply_norm(cfg, p["ln1"], h)
    q, k, v = attn_lib.qkv(cfg, p["attn"], x, positions)
    rows = jnp.arange(B)[:, None]
    if flags.kv_quant:
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        new_entry = {
            "k": cache_entry["k"].at[rows, positions].set(kq, mode="drop"),
            "v": cache_entry["v"].at[rows, positions].set(vq, mode="drop"),
            "ks": cache_entry["ks"].at[rows, positions].set(ks, mode="drop"),
            "vs": cache_entry["vs"].at[rows, positions].set(vs, mode="drop")}
        k_cache = _kv_dequantize(new_entry["k"], new_entry["ks"], h.dtype)
        v_cache = _kv_dequantize(new_entry["v"], new_entry["vs"], h.dtype)
    else:
        k_cache = cache_entry["k"].at[rows, positions].set(
            k.astype(cache_entry["k"].dtype), mode="drop")
        v_cache = cache_entry["v"].at[rows, positions].set(
            v.astype(cache_entry["v"].dtype), mode="drop")
        new_entry = {"k": k_cache, "v": v_cache}
    o = attn_lib.attend_extend(cfg, q, k_cache, v_cache, pos0,
                               window=_window(cfg, kind))
    h = h + attn_lib.out_proj(p["attn"], o)
    x2 = common.apply_norm(cfg, p["ln2"], h)
    f, _ = _ffn(cfg, p, x2, flags)
    h = h + f
    return h, new_entry


# ----- tree-verification step (T3 speculative decoding) ---------------------
def _block_step_tree(cfg: ModelConfig, p: Params, h: jnp.ndarray,
                     cache_entry: Any, mask: jnp.ndarray,
                     positions: jnp.ndarray, scratch_off: int,
                     flags: ModelFlags,
                     pages: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, Any]:
    """Process N tree tokens at once against a cache with N scratch slots.

    h: (B, N, D); mask: (1|B, 1, N, S+N) boolean (context + ancestor);
    positions: (B, N) absolute positions; scratch_off: static int — tree K/V
    land at LOGICAL cache slots [scratch_off, scratch_off+N) (page-table
    indirected when ``pages`` is set).
    Attention-family blocks only (DESIGN.md §4: T3 is restricted to
    transformer archs; SSM/hybrid use the AR engine).
    """
    B, N, D = h.shape
    x = common.apply_norm(cfg, p["ln1"], h)
    q, k, v = attn_lib.qkv(cfg, p["attn"], x, positions)
    if pages is None:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache_entry["k"], k.astype(cache_entry["k"].dtype), scratch_off,
            axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache_entry["v"], v.astype(cache_entry["v"].dtype), scratch_off,
            axis=1)
        k_cache, v_cache = new_k, new_v
    else:
        scratch_pos = jnp.broadcast_to(
            scratch_off + jnp.arange(N, dtype=jnp.int32)[None, :], (B, N))
        new_k = paged_lib.scatter_slab(cache_entry["k"], pages, scratch_pos, k)
        new_v = paged_lib.scatter_slab(cache_entry["v"], pages, scratch_pos, v)
        k_cache = paged_lib.gather_view(new_k, pages)
        v_cache = paged_lib.gather_view(new_v, pages)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = attn_lib._repeat_kv(k_cache, n_rep)
    vv = attn_lib._repeat_kv(v_cache, n_rep)
    o = attn_lib.sdpa(q, kk, vv, mask)
    h = h + attn_lib.out_proj(p["attn"], o)
    x2 = common.apply_norm(cfg, p["ln2"], h)
    f, _ = _ffn(cfg, p, x2, flags)
    h = h + f
    return h, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------
def _empty_cache_entry(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                       dtype, kv_quant: bool = False) -> Any:
    hd = cfg.resolved_head_dim()
    if kind in (ATTN, LOCAL_ATTN):
        shape = (batch, max_seq, cfg.num_kv_heads, hd)
        if kv_quant:
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(shape[:-1], jnp.float32),
                    "vs": jnp.zeros(shape[:-1], jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == RGLRU:
        w = rglru_lib.lru_width(cfg)
        K = (cfg.rglru.conv_kernel if cfg.rglru else 4)
        return {"h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, K - 1, w), dtype)}
    if kind == SSD:
        s = cfg.ssm or SSMConfig()
        di, nh, hdim, ds = ssd_lib.dims(cfg)
        return {"state": jnp.zeros((batch, nh, hdim, ds), jnp.float32),
                "conv": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * ds), dtype)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
class Model:
    def __init__(self, run: RunConfig, flags: ModelFlags = ModelFlags()):
        self.run = run
        self.cfg = run.model
        self.flags = flags
        self.segments = segments_of(list(self.cfg.blocks()))
        # exit points: one per unit instance, across segments
        self.units_per_segment = [reps for _, reps in self.segments]
        self.num_exit_points = sum(self.units_per_segment)
        # map exit point -> index of last absolute layer inside that unit
        self.exit_point_layers: List[int] = []
        abs_layer = 0
        for unit, reps in self.segments:
            for _ in range(reps):
                abs_layer += len(unit)
                self.exit_point_layers.append(abs_layer - 1)

    # ----- init -----
    def init(self, key) -> Params:
        cfg = self.cfg
        kg = KeyGen(key)
        params: Params = {"embed": common.init_embedding(cfg, kg)}
        fe = frontends.init_frontend(cfg, kg)
        if fe is not None:
            params["frontend"] = fe
        seg_params = []
        for unit, reps in self.segments:
            def init_one(k):
                kg2 = KeyGen(k)
                return {f"u{i}": _init_block(cfg, kind, kg2)
                        for i, kind in enumerate(unit)}
            keys = jax.random.split(kg(), reps)
            stacked = jax.vmap(init_one)(keys)
            seg_params.append(stacked)
        params["segments"] = seg_params
        params["final_norm"] = common.init_norm(cfg, cfg.d_model)
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": common.normal_init(kg(), (cfg.d_model, cfg.vocab_size),
                                        1.0 / math.sqrt(cfg.d_model))}
        return params

    def param_dtype_cast(self, params: Params, dtype) -> Params:
        return common.cast_tree(params, dtype)

    # ----- embedding / head -----
    def embed(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        return common.embed_tokens(params["embed"], tokens,
                                   common.dtype_of(self.cfg.dtype))

    def final_norm(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        return common.apply_norm(self.cfg, params["final_norm"], h)

    def logits(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        w = common.lm_head_weight(params)
        return (self.final_norm(params, h) @ w.astype(h.dtype)).astype(jnp.float32)

    def lm_head_columns(self, params: Params, h: jnp.ndarray,
                        token_ids: jnp.ndarray) -> jnp.ndarray:
        """Speculative LM head: logits only for ``token_ids``.

        h: (B, D) (pre-final-norm); token_ids: (B, k) -> (B, k) fp32 logits.
        """
        w = common.lm_head_weight(params)                       # (D, V)
        hn = self.final_norm(params, h)
        cols = w.T[token_ids]                                   # (B, k, D)
        return jnp.einsum("bd,bkd->bk", hn.astype(jnp.float32),
                          cols.astype(jnp.float32))

    # ----- sequence forward -----
    def forward_hidden(self, params: Params, h: jnp.ndarray,
                       positions: jnp.ndarray, collect_cache: bool = False
                       ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
        """h: (B, S, D). Returns (h_final, caches_per_segment|None, aux_loss)."""
        cfg, flags = self.cfg, self.flags
        aux_total = jnp.float32(0.0)
        h = _wsc(h, flags)
        seg_caches = []
        for si, (unit, reps) in enumerate(self.segments):
            def body(h_carry, unit_params):
                aux_sum = jnp.float32(0.0)
                caches = {}
                hc = h_carry
                for i, kind in enumerate(unit):
                    hc, ce, aux = _block_seq(cfg, kind, unit_params[f"u{i}"],
                                             hc, positions, flags, collect_cache)
                    hc = _wsc(hc, flags)
                    if collect_cache:
                        caches[f"u{i}"] = jax.tree_util.tree_map(
                            lambda t: _wsc(t, flags), ce)
                    aux_sum = aux_sum + aux
                return hc, (caches, aux_sum)
            if flags.remat == "full":
                body = jax.checkpoint(body)
            if flags.unroll:
                caches_l, aux_l = [], []
                for r in range(reps):
                    up = jax.tree_util.tree_map(lambda x: x[r],
                                                params["segments"][si])
                    h, (c, a) = body(h, up)
                    caches_l.append(c)
                    aux_l.append(a)
                caches = (jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *caches_l)
                    if collect_cache else None)
                auxs = jnp.stack(aux_l)
            else:
                h, (caches, auxs) = jax.lax.scan(body, h,
                                                 params["segments"][si])
            aux_total = aux_total + jnp.sum(auxs)
            seg_caches.append(caches if collect_cache else None)
        return h, (seg_caches if collect_cache else None), aux_total

    # ----- training -----
    def train_loss(self, params: Params, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        if cfg.frontend == "audio_frames":
            h = frontends.apply_frontend(cfg, params["frontend"],
                                         batch["frames"], dtype)
            positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None, :],
                                         h.shape[:2])
            h, _, aux = self.forward_hidden(params, h, positions)
            logits = self.logits(params, h)                      # (B,S,V)
            tgt = batch["targets"]
            mask = batch["mask"].astype(jnp.float32)
            lse = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(lse, tgt[..., None], axis=-1)[..., 0]
            loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return loss + aux, {"ce": loss, "aux": aux}
        tokens = batch["tokens"]                                 # (B, S)
        h = self.embed(params, tokens)
        if cfg.frontend == "vision_patches":
            fe = frontends.apply_frontend(cfg, params["frontend"],
                                          batch["patches"], dtype)
            h = jnp.concatenate([fe, h], axis=1)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        h, _, aux = self.forward_hidden(params, h, positions)
        # next-token prediction on the text region
        txt0 = h.shape[1] - tokens.shape[1]
        loss = self._ce_loss(params, h[:, txt0:-1, :], tokens[:, 1:],
                             chunk=self.flags.ce_chunk)
        return loss + aux, {"ce": loss, "aux": aux}

    def _ce_loss(self, params: Params, h: jnp.ndarray,
                 targets: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
        """Cross-entropy without materializing the (B, S, V) logits: scan
        over sequence chunks with per-chunk recompute (``jax.checkpoint``) —
        peak logits memory is (B, chunk, V/TP)."""
        cfg = self.cfg
        B, S, D = h.shape
        if S * cfg.vocab_size <= (1 << 24):      # small: direct path
            logits = self.logits(params, h)
            lse = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(lse, targets[..., None], axis=-1)[..., 0]
            return -jnp.mean(ll)
        chunk = min(chunk, S)
        pad = (-S) % chunk
        w = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
        hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        tp = jnp.pad(targets, ((0, 0), (0, pad)))
        nc = hp.shape[1] // chunk
        hc = jnp.moveaxis(hp.reshape(B, nc, chunk, D), 1, 0)
        tc = jnp.moveaxis(tp.reshape(B, nc, chunk), 1, 0)
        wc = jnp.moveaxis(w.reshape(B, nc, chunk), 1, 0)

        @jax.checkpoint
        def body(acc, xs):
            h_c, t_c, w_c = xs
            logits = self.logits(params, h_c)                  # (B, c, V)
            lse = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(lse, t_c[..., None], axis=-1)[..., 0]
            return acc - jnp.sum(ll * w_c), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, tc, wc))
        return total / (B * S)

    # ----- prefill -----
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                max_seq: Optional[int] = None
                ) -> Tuple[jnp.ndarray, Any, Dict[str, jnp.ndarray]]:
        """Returns (logits of last position (B, V), cache, extras).

        extras["h_final"]: (B, S, D) pre-final-norm hidden of every position
        (consumed by the SpecEE draft prefill and predictor training)."""
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        if cfg.frontend == "audio_frames":
            h = frontends.apply_frontend(cfg, params["frontend"],
                                         batch["frames"], dtype)
        else:
            h = self.embed(params, batch["tokens"])
            if cfg.frontend == "vision_patches":
                fe = frontends.apply_frontend(cfg, params["frontend"],
                                              batch["patches"], dtype)
                h = jnp.concatenate([fe, h], axis=1)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        h, caches, _ = self.forward_hidden(params, h, positions,
                                           collect_cache=True)
        if not cfg.is_decoder():
            # encoder: return frame logits, no cache semantics
            return self.logits(params, h), None, {"h_final": h}
        cache = self._materialize_cache(caches, S, max_seq or (S + 1), dtype)
        return self.logits(params, h[:, -1, :]), cache, {"h_final": h}

    def _materialize_cache(self, seg_caches, S: int, max_seq: int, dtype):
        """Pad prefill K/V to max_seq slots; wrap with position counter."""
        cfg = self.cfg
        out_segs = []
        for (unit, reps), caches in zip(self.segments, seg_caches):
            entry = {}
            for i, kind in enumerate(unit):
                ce = caches[f"u{i}"]
                if kind in (ATTN, LOCAL_ATTN):
                    def pad(x, dt=dtype):
                        padding = [(0, 0)] * x.ndim
                        padding[2] = (0, max_seq - S)
                        return jnp.pad(x, padding).astype(dt)
                    if self.flags.kv_quant:
                        kq, ks = _kv_quantize(ce["k"])
                        vq, vs = _kv_quantize(ce["v"])
                        entry[f"u{i}"] = {"k": pad(kq, jnp.int8),
                                          "v": pad(vq, jnp.int8),
                                          "ks": pad(ks, jnp.float32),
                                          "vs": pad(vs, jnp.float32)}
                    else:
                        entry[f"u{i}"] = {"k": pad(ce["k"]),
                                          "v": pad(ce["v"])}
                else:
                    entry[f"u{i}"] = ce
            out_segs.append(entry)
        B = jax.tree_util.tree_leaves(out_segs[0])[0].shape[1]
        return {"segments": out_segs, "len": jnp.full((B,), S, jnp.int32)}

    def empty_cache(self, batch: int, max_seq: int) -> Any:
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        segs = []
        for unit, reps in self.segments:
            entry = {}
            for i, kind in enumerate(unit):
                one = _empty_cache_entry(cfg, kind, batch, max_seq, dtype,
                                         self.flags.kv_quant)
                entry[f"u{i}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one)
            segs.append(entry)
        return {"segments": segs, "len": jnp.zeros((batch,), jnp.int32)}

    # ----- chunked prefill (Sarathi-style admission) -----
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill needs blocks whose state extension is expressible
        as "write K/V, attend prefix" — attention-family only (DESIGN.md §4).
        Recurrent/SSD and frontend archs admit with one whole-prompt chunk.
        """
        return (self.cfg.is_decoder() and self.cfg.frontend == "none" and
                all(k in (ATTN, LOCAL_ATTN)
                    for unit, _ in self.segments for k in unit))

    def prefill_extend(self, params: Params, tokens: jnp.ndarray, cache: Any,
                       n_valid) -> Tuple[jnp.ndarray, Any]:
        """Extend a DENSE decode cache with one prompt chunk.

        tokens: (B, C) int32, first ``n_valid`` real (the tail is padding
        whose K/V lands past the prompt and is later overwritten or masked —
        intra-chunk causality already hides it from real queries).
        Returns (h (B, C, D) pre-final-norm hiddens, cache with
        ``len += n_valid``). The admission path of ``DecodeSession.
        prefill_chunk`` jits exactly this."""
        assert self.supports_chunked_prefill(), \
            f"{self.cfg.name}: chunked prefill requires a pure-attention " \
            "decoder stack (DESIGN.md §4)"
        h = self.embed(params, tokens)                       # (B, C, D)
        pos0 = cache["len"]
        B, C = tokens.shape
        positions = pos0[:, None] + jnp.arange(C)[None, :]
        new_segs = []
        for seg in range(len(self.segments)):
            def body(carry, xs):
                hc = carry
                unit_params, entry = xs
                new_entry = {}
                for i, kind in enumerate(self.segments[seg][0]):
                    hc, ne = _block_extend(self.cfg, kind,
                                           unit_params[f"u{i}"], hc,
                                           entry[f"u{i}"], pos0, positions,
                                           self.flags)
                    new_entry[f"u{i}"] = jax.tree_util.tree_map(
                        lambda n, o: n.astype(o.dtype), ne, entry[f"u{i}"])
                return _wsc(hc, self.flags), new_entry

            h, new_seg_cache = jax.lax.scan(
                body, h, (params["segments"][seg], cache["segments"][seg]))
            new_segs.append(new_seg_cache)
        return h, dict(cache, segments=new_segs,
                       len=pos0 + jnp.asarray(n_valid, jnp.int32))

    # ----- layer-granular decode API (SpecEE engine) -----
    def run_unit(self, params: Params, seg: int, unit_idx: jnp.ndarray,
                 h: jnp.ndarray, seg_cache: Any, pos: jnp.ndarray,
                 live_mask: Optional[jnp.ndarray] = None,
                 pages: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Any]:
        """Run unit ``unit_idx`` (dynamic) of segment ``seg`` (static) on one
        token. h: (B, D). seg_cache: the stacked cache of this segment.
        ``pages``: the session page table when the cache is paged.
        Returns (h_out, updated seg_cache)."""
        unit, reps = self.segments[seg]
        up = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            params["segments"][seg])
        ce = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            seg_cache)
        new_entries = {}
        for i, kind in enumerate(unit):
            h, ne = _block_step(self.cfg, kind, up[f"u{i}"], h, ce[f"u{i}"],
                                pos, self.flags, live_mask, pages=pages)
            new_entries[f"u{i}"] = ne
        seg_cache = jax.tree_util.tree_map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), unit_idx, 0),
            seg_cache, new_entries)
        return _wsc(h, self.flags), seg_cache

    def propagate_unit(self, params: Params, seg: int, unit_idx: jnp.ndarray,
                       h: jnp.ndarray, seg_cache: Any, pos: jnp.ndarray,
                       pages: Optional[jnp.ndarray] = None) -> Any:
        """KV/state propagation for a skipped unit (SpecEE early exit)."""
        unit, reps = self.segments[seg]
        up = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            params["segments"][seg])
        ce = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            seg_cache)
        new_entries = {}
        for i, kind in enumerate(unit):
            new_entries[f"u{i}"] = _block_propagate(
                self.cfg, kind, up[f"u{i}"], h, ce[f"u{i}"], pos, self.flags,
                pages=pages)
        return jax.tree_util.tree_map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), unit_idx, 0),
            seg_cache, new_entries)

    # ----- tree-verification API (T3) -----
    def supports_tree(self) -> bool:
        return all(k == ATTN for unit, _ in self.segments for k in unit)

    def run_unit_tree(self, params: Params, seg: int, unit_idx: jnp.ndarray,
                      h: jnp.ndarray, seg_cache: Any, mask: jnp.ndarray,
                      positions: jnp.ndarray, scratch_off: int,
                      pages: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Any]:
        """Tree analogue of ``run_unit``: h is (B, N, D) tree-node hiddens."""
        unit, reps = self.segments[seg]
        up = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            params["segments"][seg])
        ce = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            seg_cache)
        new_entries = {}
        for i, kind in enumerate(unit):
            assert kind == ATTN, "tree mode requires pure-attention stacks"
            h, ne = _block_step_tree(self.cfg, up[f"u{i}"], h, ce[f"u{i}"],
                                     mask, positions, scratch_off, self.flags,
                                     pages=pages)
            new_entries[f"u{i}"] = ne
        seg_cache = jax.tree_util.tree_map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), unit_idx, 0),
            seg_cache, new_entries)
        return h, seg_cache

    def propagate_unit_tree(self, params: Params, seg: int,
                            unit_idx: jnp.ndarray, h: jnp.ndarray,
                            seg_cache: Any, positions: jnp.ndarray,
                            scratch_off: int,
                            pages: Optional[jnp.ndarray] = None) -> Any:
        """KV propagation for tree scratch slots of a skipped unit."""
        unit, reps = self.segments[seg]
        up = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            params["segments"][seg])
        ce = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, unit_idx, 0, False),
            seg_cache)
        N = h.shape[1]
        scratch_pos = scratch_off + jnp.arange(N, dtype=jnp.int32)[None, :]
        new_entries = {}
        for i, kind in enumerate(unit):
            p = up[f"u{i}"]
            x = common.apply_norm(self.cfg, p["ln1"], h)
            k, v = attn_lib.kv_only(self.cfg, p["attn"], x, positions)
            entry = ce[f"u{i}"]
            if pages is None:
                new_entries[f"u{i}"] = {
                    "k": jax.lax.dynamic_update_slice_in_dim(
                        entry["k"], k.astype(entry["k"].dtype), scratch_off,
                        axis=1),
                    "v": jax.lax.dynamic_update_slice_in_dim(
                        entry["v"], v.astype(entry["v"].dtype), scratch_off,
                        axis=1),
                }
            else:
                pos_mat = jnp.broadcast_to(scratch_pos, (h.shape[0], N))
                new_entries[f"u{i}"] = {
                    "k": paged_lib.scatter_slab(entry["k"], pages, pos_mat, k),
                    "v": paged_lib.scatter_slab(entry["v"], pages, pos_mat, v),
                }
        return jax.tree_util.tree_map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), unit_idx, 0),
            seg_cache, new_entries)

    def accept_tree_kv(self, cache: Any, accepted_nodes: jnp.ndarray,
                       accepted_len: jnp.ndarray, pos0: jnp.ndarray,
                       scratch_off: int) -> Any:
        """Copy the K/V of accepted tree nodes from scratch slots into their
        real positions. accepted_nodes: (B, Dmax) node ids (-1 pad);
        accepted_len: (B,); node at chain index d lands at pos0+d. Paged
        caches (``cache["page_table"]``) route the copy through the table."""
        B, Dmax = accepted_nodes.shape
        rows = jnp.arange(B)
        pages = cache.get("page_table")

        def copy_leaf(x):
            # dense: x (reps, B, S+N, kvh, hd)
            for d in range(Dmax):
                node = accepted_nodes[:, d]
                valid = (d < accepted_len) & (node >= 0)
                src = x[:, rows, scratch_off + jnp.maximum(node, 0)]
                dst = x[:, rows, pos0 + d]
                x = x.at[:, rows, pos0 + d].set(
                    jnp.where(valid[None, :, None, None], src, dst))
            return x

        def copy_leaf_paged(x):
            # paged: x (reps, n_pages, ps, kvh, hd) — per-row logical slots
            # resolve through the page table
            ps = x.shape[2]
            xf = x.reshape((x.shape[0], x.shape[1] * ps) + x.shape[3:])
            for d in range(Dmax):
                node = accepted_nodes[:, d]
                valid = (d < accepted_len) & (node >= 0)
                src_slot = paged_lib.flat_slots(
                    pages, ps, scratch_off + jnp.maximum(node, 0))
                dst_slot = paged_lib.flat_slots(pages, ps, pos0 + d)
                src = xf[:, src_slot]                       # (reps, B, ...)
                dst = xf[:, dst_slot]
                vb = valid.reshape((1, B) + (1,) * (src.ndim - 2))
                xf = xf.at[:, dst_slot].set(jnp.where(vb, src, dst))
            return xf.reshape(x.shape)

        new_segs = []
        for seg, (unit, reps) in enumerate(self.segments):
            fn = copy_leaf if pages is None else copy_leaf_paged
            new_segs.append(jax.tree_util.tree_map(fn, cache["segments"][seg]))
        return dict(cache, segments=new_segs)

    # ----- dense decode (baseline, no early exit) -----
    def decode_step(self, params: Params, token: jnp.ndarray, cache: Any
                    ) -> Tuple[jnp.ndarray, Any]:
        """token: (B,) int32. Returns (logits (B, V) fp32, new cache)."""
        h, cache = self.decode_step_hidden(params, token, cache)
        return self.logits(params, h), cache

    def decode_step_hidden(self, params: Params, token: jnp.ndarray,
                           cache: Any) -> Tuple[jnp.ndarray, Any]:
        """Full-depth decode returning the PRE-final-norm hidden instead of
        logits — the emit (LM head) is the caller's: ``dense_decode_step``
        streams it through ``verify_argmax`` so greedy dense decode never
        materializes the (B, V) logits either.
        token: (B,) int32. Returns (h (B, D), new cache)."""
        h = self.embed(params, token[:, None])[:, 0, :]          # (B, D)
        pos = cache["len"]
        pages = cache.get("page_table")
        new_segs = []
        for seg in range(len(self.segments)):
            seg_cache = cache["segments"][seg]
            reps = self.segments[seg][1]

            def body(carry, xs):
                h_c = carry
                unit_params, entry = xs
                new_entry = {}
                hc = h_c
                for i, kind in enumerate(self.segments[seg][0]):
                    hc, ne = _block_step(self.cfg, kind, unit_params[f"u{i}"],
                                         hc, entry[f"u{i}"], pos, self.flags,
                                         pages=pages)
                    new_entry[f"u{i}"] = jax.tree_util.tree_map(
                        lambda n, o: n.astype(o.dtype), ne, entry[f"u{i}"])
                return _wsc(hc, self.flags), new_entry

            if self.flags.unroll:
                reps_n = self.segments[seg][1]
                outs = []
                for r in range(reps_n):
                    up = jax.tree_util.tree_map(lambda x: x[r],
                                                params["segments"][seg])
                    ce = jax.tree_util.tree_map(lambda x: x[r], seg_cache)
                    h, ne = body(h, (up, ce))
                    outs.append(ne)
                new_seg_cache = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *outs)
            else:
                h, new_seg_cache = jax.lax.scan(
                    body, h, (params["segments"][seg], seg_cache))
            new_segs.append(new_seg_cache)
        return h, dict(cache, segments=new_segs, len=pos + 1)


def build_model(run: RunConfig, flags: ModelFlags = ModelFlags()) -> Model:
    return Model(run, flags)
