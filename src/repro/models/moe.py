"""Mixture-of-Experts FFN with top-k routing (dbrx / qwen3-moe).

Dense-compute formulation (every expert computes, outputs combined by router
weights) for small/smoke paths, and a dispatch ("einsum MoE", Shazeer-style
one-hot combine) formulation whose expert dimension shards cleanly over the
mesh 'data' axis (expert parallelism) for the production path. Both are
mathematically identical for top-k routing without capacity dropping.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, Params


def init_moe(cfg: ModelConfig, kg: KeyGen) -> Params:
    assert cfg.moe is not None
    e = cfg.moe
    d, f, E = cfg.d_model, e.expert_d_ff, e.num_experts
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.num_layers)
    p: Params = {
        "router": {"w": common.normal_init(kg(), (d, E), std_in)},
        "wi": common.normal_init(kg(), (E, d, f), std_in),
        "wo": common.normal_init(kg(), (E, f, d), std_out),
    }
    if cfg.gated_mlp:
        p["wg"] = common.normal_init(kg(), (E, d, f), std_in)
    return p


def router_probs(cfg: ModelConfig, p: Params,
                 x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) -> (combine_weights (..., E), router_logits (..., E)).

    Top-k selection with renormalized softmax over the selected experts.
    one_hot-based combine keeps arbitrary leading batch dims (and their
    shardings) intact.
    """
    e = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    topv, topi = jax.lax.top_k(logits, e.num_experts_per_tok)       # (...,k)
    gate = jax.nn.softmax(topv, axis=-1)
    onehot = jax.nn.one_hot(topi, e.num_experts, dtype=gate.dtype)  # (...,k,E)
    combine = jnp.einsum("...k,...ke->...e", gate, onehot)
    return combine, logits


def _pin_experts(t: jnp.ndarray, ep_axes, ep_extent: int) -> jnp.ndarray:
    """Pin dim 0 (experts) of an intermediate to the EP mesh axes so GSPMD
    computes each device's local experts over (gathered) tokens instead of
    re-sharding the expert weights per chunk. Falls back to the 'data' axis
    alone when E doesn't divide (pod×data) — EP stays within a pod."""
    if ep_axes is None:
        return t
    if t.shape[0] % max(ep_extent, 1):
        if (isinstance(ep_axes, tuple) and "data" in ep_axes
                and t.shape[0] % 16 == 0):
            ep_axes = "data"
        else:
            return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(ep_axes, *([P.UNCONSTRAINED] * (t.ndim - 1))))


def _ep_quantized_gather(xc: jnp.ndarray, ep_axes) -> jnp.ndarray:
    """§Perf beyond-paper lever: quantize tokens to int8 BEFORE the EP
    all-gather. Pinning the int8 tensor to the gathered (batch-replicated)
    layout forces GSPMD to move 1-byte payloads over the ICI instead of
    bf16 — halving the dominant EP collective term. Dequantized immediately
    after; per-token scales ride along (negligible bytes)."""
    from jax.sharding import PartitionSpec as P
    amax = jnp.max(jnp.abs(xc.astype(jnp.float32)), axis=-1) + 1e-8
    scale = (amax / 127.0).astype(jnp.float32)                  # (B, Sc)
    q = jnp.clip(jnp.round(xc.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    rest = [P.UNCONSTRAINED] * (q.ndim - 1)
    q = jax.lax.with_sharding_constraint(q, P(None, *rest))     # gather int8
    scale = jax.lax.with_sharding_constraint(
        scale, P(None, *([P.UNCONSTRAINED] * (scale.ndim - 1))))
    return (q.astype(jnp.float32) * scale[..., None]).astype(xc.dtype)


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              token_chunk: int = 4096, ep_axes=None,
              ep_extent: int = 1,
              ep_quant: bool = False,
              bf16_reduce: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Einsum formulation: activations are projected through every expert and
    combined with the (mostly-zero) combine weights. On a sharded mesh the
    expert dimension E lives on the EP axis, so each device computes only its
    local experts — the zero-weight math is free after SPMD partitioning of
    the E dimension, and the combine turns into a reduce over EP.

    The SEQUENCE dim is processed in ``token_chunk`` chunks (``lax.scan``) so
    the all-expert activation tensor (E_local, B, Sc, F) stays HBM-bounded at
    32k prefill; the batch dim is kept explicit through every einsum so its
    'data' sharding survives (no reshape → no GSPMD all-gather).
    """
    B, S, D = x.shape
    act = common.activation_fn(cfg.activation)

    def ffn(xc):  # (B, Sc, D)
        combine, logits = router_probs(cfg, p, xc)                  # (B,Sc,E)
        combine = combine.astype(x.dtype)
        if ep_quant and ep_axes is not None:
            xc = _ep_quantized_gather(xc, ep_axes)
        up = jnp.einsum("bsd,edf->ebsf", xc, p["wi"].astype(x.dtype))
        up = _pin_experts(up, ep_axes, ep_extent)
        if cfg.gated_mlp:
            gate_h = jnp.einsum("bsd,edf->ebsf", xc, p["wg"].astype(x.dtype))
            gate_h = _pin_experts(gate_h, ep_axes, ep_extent)
            up = act(gate_h) * up
        else:
            up = act(up)
        # weight the expert activations by the router BEFORE the down
        # projection and contract E and F together — the (E, B, Sc, D)
        # per-expert output tensor never materializes
        up = up * jnp.moveaxis(combine, -1, 0)[..., None]           # (E,B,Sc,F)
        up = _pin_experts(up, ep_axes, ep_extent)
        # bf16_reduce: the E/F contraction's cross-device partial sums move
        # bf16 on the ICI instead of f32 (local accumulation over at most
        # E_local×F_local ≤ a few hundred terms — bounded error)
        pet = jnp.bfloat16 if bf16_reduce else None
        out = jnp.einsum("ebsf,efd->bsd", up, p["wo"].astype(x.dtype),
                         preferred_element_type=pet).astype(x.dtype)
        return out, load_balancing_loss(cfg, logits.reshape(-1,
                                                            logits.shape[-1]))

    if S <= token_chunk:
        return ffn(x)

    chunk = token_chunk
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)             # (nc,B,c,D)

    def body(_, cx):
        out, aux = ffn(cx)
        return None, (out, aux)

    _, (outs, auxs) = jax.lax.scan(body, None, xc)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, D)
    return out, jnp.mean(auxs)


def apply_moe_topk(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather-based top-k MoE: computes only the selected experts per token.

    FLOP-proportional to k/E (the serving path for CPU benchmarks); identical
    output to ``apply_moe``.
    """
    e = cfg.moe
    B, S, D = x.shape
    act = common.activation_fn(cfg.activation)
    xt = x.reshape(B * S, D)
    logits = xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    topv, topi = jax.lax.top_k(logits, e.num_experts_per_tok)
    gate = jax.nn.softmax(topv, axis=-1).astype(x.dtype)            # (T, k)

    wi = p["wi"].astype(x.dtype)[topi]                              # (T, k, D, F)
    wo = p["wo"].astype(x.dtype)[topi]                              # (T, k, F, D)
    up = jnp.einsum("td,tkdf->tkf", xt, wi)
    if cfg.gated_mlp:
        wg = p["wg"].astype(x.dtype)[topi]
        up = act(jnp.einsum("td,tkdf->tkf", xt, wg)) * up
    else:
        up = act(up)
    down = jnp.einsum("tkf,tkfd->tkd", up, wo)                      # (T, k, D)
    out = jnp.einsum("tkd,tk->td", down, gate)
    aux = load_balancing_loss(cfg, logits)
    return out.reshape(B, S, D), aux


def load_balancing_loss(cfg: ModelConfig, router_logits: jnp.ndarray) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * p_e (f = fraction routed, p = mean prob)."""
    e = cfg.moe
    probs = jax.nn.softmax(router_logits, axis=-1)                  # (T, E)
    _, topi = jax.lax.top_k(router_logits, e.num_experts_per_tok)
    onehot = jax.nn.one_hot(topi, e.num_experts, dtype=jnp.float32)  # (T, k, E)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)                   # (E,)
    pm = jnp.mean(probs, axis=0)
    return e.num_experts * jnp.sum(f * pm) * e.router_aux_loss_weight
