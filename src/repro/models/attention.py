"""Attention blocks: GQA, causal / bidirectional / sliding-window, KV cache.

Reference jnp implementations; the Pallas kernels in ``repro.kernels`` are
drop-in replacements selected via ``repro.models.model.KernelFlags``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, Params

NEG_INF = -1e30


def init_attention(cfg: ModelConfig, kg: KeyGen) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    out_std = 1.0 / math.sqrt(cfg.num_heads * hd) / math.sqrt(2 * cfg.num_layers)
    return {
        "wq": common.init_linear(kg, d, cfg.num_heads * hd, cfg.use_bias),
        "wk": common.init_linear(kg, d, cfg.num_kv_heads * hd, cfg.use_bias),
        "wv": common.init_linear(kg, d, cfg.num_kv_heads * hd, cfg.use_bias),
        "wo": common.init_linear(kg, cfg.num_heads * hd, d, cfg.use_bias,
                                 std=out_std),
    }


def qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray,
        positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> q (B,S,H,hd), k,v (B,S,KVH,hd), with RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    q = common.apply_linear(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = common.apply_linear(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = common.apply_linear(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.causal:  # decoder archs use RoPE; the encoder (hubert) is position-free here
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def kv_only(cfg: ModelConfig, p: Params, x: jnp.ndarray,
            positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K/V projections only — used for SpecEE KV propagation of skipped layers."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    k = common.apply_linear(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = common.apply_linear(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.causal:
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, KVH, hd) -> (B, S, KVH*n_rep, hd)."""
    if n_rep == 1:
        return x
    B, S, KVH, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (B, S, KVH, n_rep, hd))
    return x.reshape(B, S, KVH * n_rep, hd)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Reference scaled-dot-product attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd); mask: broadcastable to
    (B, H, Sq, Sk) boolean (True = attend). Softmax in fp32.
    """
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def causal_mask(Sq: int, Sk: int, q_offset: int = 0,
                window: Optional[int] = None) -> jnp.ndarray:
    """(1, 1, Sq, Sk) boolean mask; window = sliding-window size (None=global)."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend_full(cfg: ModelConfig, q, k, v,
                window: Optional[int] = None) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill path)."""
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    S = q.shape[1]
    mask = causal_mask(S, S, 0, window) if cfg.causal else None
    return sdpa(q, k, v, mask)


def attend_full_chunked(cfg: ModelConfig, q, k, v,
                        window: Optional[int] = None,
                        chunk: int = 512) -> jnp.ndarray:
    """Memory-efficient exact attention: ``lax.scan`` over query chunks so the
    peak logits tensor is (B, H, chunk, S) instead of (B, H, S, S).

    This is the jnp analogue of the Pallas flash kernel used for HLO-level
    dry-runs (the kernel itself only lowers on real TPUs). Keys are not
    causally pruned per chunk (static shapes), costing ≤2× attention FLOPs
    over the ideal — accounted for in EXPERIMENTS.md §Roofline.
    """
    B, S, H, hd = q.shape
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nq = S // chunk
    qc = jnp.moveaxis(q.reshape(B, nq, chunk, H, hd), 1, 0)   # (nq,B,c,H,hd)

    kpos = jnp.arange(S)[None, :]

    def body(_, args):
        i, qb = args
        out = None
        if cfg.causal:
            qpos = i * chunk + jnp.arange(chunk)[:, None]
            m = kpos <= qpos
            if window is not None:
                m = m & (kpos > qpos - window)
            mask = m[None, None]                              # (1,1,c,S)
        else:
            mask = None
        return None, sdpa(qb, k, v, mask)

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attend_full_chunked_pruned(cfg: ModelConfig, q, k, v,
                               window: Optional[int] = None,
                               chunk: int = 512) -> jnp.ndarray:
    """Causally-PRUNED chunked attention (§Perf beyond-paper lever).

    Like ``attend_full_chunked`` but the inner KV loop is a ``fori_loop``
    whose upper bound depends on the query chunk (and lower bound on the
    sliding window) — strictly-above-diagonal KV blocks are never computed,
    recovering the ~2× causal FLOP saving that static-shape chunking wastes
    (this is the jnp analogue of the Pallas kernel's ``pl.when`` block skip).
    Online-softmax accumulation keeps it exact. Causal only.
    """
    assert cfg.causal
    B, S, H, hd = q.shape
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nq = S // chunk
    scale = 1.0 / math.sqrt(hd)
    qc = jnp.moveaxis(q.reshape(B, nq, chunk, H, hd), 1, 0)

    def q_body(_, args):
        i, qb = args                                   # qb: (B, c, H, hd)
        qf = jnp.moveaxis(qb, 2, 1).astype(jnp.float32) * scale  # (B,H,c,hd)

        def kv_step(j, carry):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
            kf = jnp.moveaxis(kb, 2, 1).astype(jnp.float32)
            vf = jnp.moveaxis(vb, 2, 1).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
            qpos = i * chunk + jnp.arange(chunk)[:, None]
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            mask = kpos <= qpos
            if window is not None:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vf)
            return m_new, l, acc

        m0 = jnp.full((B, H, chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, H, chunk, hd), jnp.float32)
        lo = jnp.int32(0) if window is None else jnp.maximum(
            0, (i * chunk - window) // chunk)
        m, l, acc = jax.lax.fori_loop(lo, i + 1, kv_step, (m0, l0, a0))
        out = acc / jnp.where(l == 0.0, 1.0, l)
        return None, jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B,c,H,hd)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attend_decode(cfg: ModelConfig, q, k_cache, v_cache, cache_len,
                  window: Optional[int] = None) -> jnp.ndarray:
    """One-step decode attention against a (B, S, KVH, hd) cache.

    q: (B, 1, H, hd); cache_len: scalar or (B,) int32 — number of valid cache
    slots (the current token's k/v must already be written at cache_len-1).

    GQA is contracted with GROUPED einsums — the KV cache is never
    repeat-materialized, so a sequence-sharded (split-KV) cache stays local:
    softmax renormalization and the value contraction reduce over the shard
    with scalar-sized collectives instead of gathering GBs of cache per layer
    (measured in EXPERIMENTS.md §Perf).
    """
    B, _, H, hd = q.shape
    KVH = k_cache.shape[2]
    n_rep = H // KVH
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = q[:, 0].reshape(B, KVH, n_rep, hd)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache
                        ).astype(jnp.float32) * scale      # (B,KVH,rep,S)
    kpos = jnp.arange(S)[None, :]
    clen = jnp.reshape(cache_len, (-1, 1))      # (1,1) scalar or (B,1)
    valid = kpos < clen
    if window is not None:
        valid = valid & (kpos >= clen - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


def attend_extend(cfg: ModelConfig, q, k_cache, v_cache, start_pos,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Chunked-prefill attention: C queries extend a prefix cache.

    q: (B, C, H, hd) — chunk queries at absolute positions start_pos + i;
    k_cache/v_cache: (B, S, KVH, hd) with the chunk's K/V already written at
    those positions; start_pos: (B,) int32 prefix length. Query i attends
    kpos <= start_pos + i (prefix + intra-chunk causal), so one chunk at a
    time reproduces full causal attention exactly — this is the multi-token
    generalization of ``attend_decode`` (C = 1)."""
    B, C, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    n_rep = H // KVH
    kk, vv = _repeat_kv(k_cache, n_rep), _repeat_kv(v_cache, n_rep)
    qpos = jnp.reshape(start_pos, (-1, 1)) + jnp.arange(C)[None, :]  # (B, C)
    kpos = jnp.arange(S)
    valid = kpos[None, None, :] <= qpos[:, :, None]                  # (B,C,S)
    if window is not None:
        valid = valid & (kpos[None, None, :] > qpos[:, :, None] - window)
    return sdpa(q, kk, vv, valid[:, None])


def out_proj(p: Params, attn_out: jnp.ndarray, pet=None) -> jnp.ndarray:
    B, S, H, hd = attn_out.shape
    return common.apply_linear(p["wo"], attn_out.reshape(B, S, H * hd),
                               pet=pet)
