"""Model zoo: dense GQA / MoE transformers, Mamba2 SSD, RG-LRU hybrid,
encoder-only audio transformer, and modality frontend stubs.

Public entry point: ``repro.models.model.build_model(run_config)``.
"""
