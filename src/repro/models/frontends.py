"""Modality frontend STUBS (per assignment: the transformer backbone is the
deliverable; frontends provide precomputed patch/frame embeddings).

- vision_patches (internvl2): ``input_specs()`` supplies (B, P, d_frontend)
  patch embeddings; a learned projection maps them to d_model and they are
  prepended to the text token embeddings.
- audio_frames (hubert): frames arrive already at d_model (the conv feature
  extractor is the stub); a learned linear "feature projection" is applied.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, Params

# frontend embedding width produced by the (stubbed) modality encoder
FRONTEND_DIM = 1024


def init_frontend(cfg: ModelConfig, kg: KeyGen) -> Optional[Params]:
    if cfg.frontend == "vision_patches":
        return {"proj": common.init_linear(kg, FRONTEND_DIM, cfg.d_model, True)}
    if cfg.frontend == "audio_frames":
        return {"proj": common.init_linear(kg, cfg.d_model, cfg.d_model, True)}
    return None


def apply_frontend(cfg: ModelConfig, p: Params, feats: jnp.ndarray,
                   dtype) -> jnp.ndarray:
    """feats: (B, T, FRONTEND_DIM|d_model) -> (B, T, d_model)."""
    return common.apply_linear(p["proj"], feats.astype(dtype))


def frontend_feature_dim(cfg: ModelConfig) -> int:
    return FRONTEND_DIM if cfg.frontend == "vision_patches" else cfg.d_model
