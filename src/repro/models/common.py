"""Shared layers/utilities for the functional model zoo (pure JAX, no flax)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------
def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def normal_init(key, shape, std: float = 0.02, dtype=jnp.float32):
    return (std * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Split keys on demand: ``kg = KeyGen(key); w = init(kg(), ...)``."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: int) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": ones_init((dim,)), "bias": zeros_init((dim,))}
    return {"scale": ones_init((dim,))}


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                   # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / linear
# ---------------------------------------------------------------------------
def init_linear(kg: KeyGen, d_in: int, d_out: int, use_bias: bool,
                std: Optional[float] = None) -> Params:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": normal_init(kg(), (d_in, d_out), std)}
    if use_bias:
        p["b"] = zeros_init((d_out,))
    return p


def apply_linear(p: Params, x: jnp.ndarray, pet=None) -> jnp.ndarray:
    """pet: preferred_element_type — §Perf lever: row-parallel projections
    pass bf16 so the cross-shard partial-sum all-reduce moves 2 B/elem."""
    if pet is not None:
        y = jax.lax.dot_general(x, p["w"].astype(x.dtype),
                                (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=pet).astype(x.dtype)
    else:
        y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# MLP (gated / plain)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, kg: KeyGen, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out_std = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.num_layers)
    p: Params = {"wi": init_linear(kg, d, f, cfg.use_bias),
                 "wo": init_linear(kg, f, d, cfg.use_bias, std=out_std)}
    if cfg.gated_mlp:
        p["wg"] = init_linear(kg, d, f, cfg.use_bias)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              pet=None) -> jnp.ndarray:
    act = activation_fn(cfg.activation)
    up = apply_linear(p["wi"], x)
    if cfg.gated_mlp:
        up = act(apply_linear(p["wg"], x)) * up
    else:
        up = act(up)
    return apply_linear(p["wo"], up, pet=pet)  # row-parallel: psum dtype


# ---------------------------------------------------------------------------
# embeddings & head
# ---------------------------------------------------------------------------
def init_embedding(cfg: ModelConfig, kg: KeyGen) -> Params:
    return {"tok": normal_init(kg(), (cfg.vocab_size, cfg.d_model), 0.02)}


def embed_tokens(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["tok"].astype(dtype)[tokens]


def lm_head_weight(params: Params) -> jnp.ndarray:
    """(d_model, vocab) — transposed embedding when tied."""
    if "lm_head" in params:
        return params["lm_head"]["w"]
    return params["embed"]["tok"].T
