"""Weight-only int8/int4 compression for the decode path (DESIGN.md §8).

The paper positions SpecEE as "a framework for various existing orthogonal
acceleration techniques (e.g., quantization …)"; this package is the
weight-only half of that composition. Selected weight tensors — the LM head
the streaming verify kernels read every token, the spec-head gather, the
exit predictors, and the per-layer projections — are converted to int8 or
packed int4 with per-output-channel scales and stored in a *parallel*
pytree. The original params are never touched (the paper's "without
affecting the model original parameters" property), so training, prefill,
and any fp path keep reading the fp weights while the decode loop streams
the compressed copies.
"""
from repro.quant.core import (QTensor, QuantSpec, dequantize,
                              dequantized_reference, merge_dequant,
                              pack_int4, quantize_params, quantize_tensor,
                              take_columns, unpack_int4)

__all__ = ["QTensor", "QuantSpec", "dequantize", "dequantized_reference",
           "merge_dequant", "pack_int4", "quantize_params",
           "quantize_tensor", "take_columns", "unpack_int4"]
