"""Quantized-weight containers and the params → parallel-pytree converter.

Layout contract (shared with the fused kernels — DESIGN.md §8):

* Scales are **per output channel**: a weight ``W (..., d_in, d_out)``
  stores ``scale (..., d_out)`` fp32 and ``q`` integer codes with
  ``W ≈ q * scale[..., None, :]``. Per-column scales are what lets the
  streaming verify kernel fold the dequant *after* the tile dot product
  (the scale is constant down the contracted dimension), so the MXU still
  sees one integer-fed fp32 matmul per tile.
* int8: symmetric, codes in [-127, 127], ``scale = amax / 127``.
* int4: symmetric, codes in [-7, 7], ``scale = amax / 7``, two codes per
  byte in **plane packing**: the low nibble holds row ``i`` of the first
  half ``[0, d_in/2)`` and the high nibble row ``i + d_in/2``. Unpacking is
  a concatenation of the two planes — never an interleave — so a kernel can
  process the halves as two independent tiles (dual-h trick) and a ref path
  can reassemble with one ``concatenate``. ``d_in`` must be even (odd
  tensors silently fall back to int8).

Quantization never mutates the source pytree: ``quantize_params`` builds a
parallel structure of ``QTensor`` leaves and the engine decides per call
site whether to read the fp or the compressed copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

INT4_MAX = 7
INT8_MAX = 127


# ---------------------------------------------------------------------------
# int4 plane packing
# ---------------------------------------------------------------------------
def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack int codes in [-7, 7] along axis -2: (..., d, n) -> (..., d/2, n).

    Byte layout: ``(lo & 0xF) | (hi << 4)`` with lo = rows [0, d/2) and
    hi = rows [d/2, d) (plane packing — see module docstring).
    """
    d = codes.shape[-2]
    if d % 2:
        raise ValueError(f"int4 plane packing needs an even row count, got {d}")
    c = jnp.clip(codes.astype(jnp.int32), -INT4_MAX, INT4_MAX)
    lo, hi = jnp.split(c, 2, axis=-2)
    return ((lo & 0xF) | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of ``pack_int4``: (..., d/2, n) -> two int32 planes.

    Returns (lo, hi) sign-extended; the full matrix is their axis -2
    concatenation.
    """
    p = packed.astype(jnp.int32)
    hi = p >> 4                       # arithmetic shift: sign-extends
    lo = (p << 28) >> 28
    return lo, hi


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
class QTensor:
    """A quantized weight: integer codes + per-output-channel fp32 scales.

    ``q``: int8 codes, shape (..., d_in, d_out) for bits=8 or the packed
    (..., d_in/2, d_out) plane layout for bits=4. ``scale``: fp32,
    (..., d_out). ``bits`` is pytree aux data — static under jit, so ops
    wrappers can branch on it (and on ``isinstance(w, QTensor)``) without
    extra static arguments.
    """

    def __init__(self, q: jnp.ndarray, scale: jnp.ndarray, bits: int):
        self.q = q
        self.scale = scale
        self.bits = int(bits)

    # -- pytree protocol --
    def tree_flatten(self):
        return (self.q, self.scale), self.bits

    @classmethod
    def tree_unflatten(cls, bits, children):
        q, scale = children
        return cls(q, scale, bits)

    # -- introspection --
    @property
    def shape(self) -> Tuple[int, ...]:
        mult = 2 if self.bits == 4 else 1
        s = self.q.shape
        return s[:-2] + (s[-2] * mult, s[-1])

    @property
    def dtype(self):
        return jnp.float32

    @property
    def ndim(self) -> int:
        return self.q.ndim

    def nbytes(self) -> int:
        """Weight-stream footprint (codes + scales) in bytes."""
        return int(self.q.size) + 4 * int(self.scale.size)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"QTensor(shape={self.shape}, bits={self.bits}, "
                f"packed={self.q.shape})")

    # -- math --
    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return dequantize(self, dtype)


def quantize_tensor(w: jnp.ndarray, bits: int) -> QTensor:
    """Symmetric per-output-column quantization of ``w (..., d_in, d_out)``."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if bits == 4 and w.shape[-2] % 2:
        bits = 8                      # plane packing needs even rows
    wf = w.astype(jnp.float32)
    qmax = INT4_MAX if bits == 4 else INT8_MAX
    amax = jnp.max(jnp.abs(wf), axis=-2) + 1e-8          # (..., d_out)
    scale = (amax / qmax).astype(jnp.float32)
    codes = jnp.clip(jnp.round(wf / scale[..., None, :]), -qmax, qmax)
    if bits == 4:
        q = pack_int4(codes)
    else:
        q = codes.astype(jnp.int8)
    return QTensor(q, scale, bits)


def dequantize(qt: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    """Materialize the fp weight: codes * per-column scale."""
    if qt.bits == 4:
        lo, hi = unpack_int4(qt.q)
        codes = jnp.concatenate([lo, hi], axis=-2)
    else:
        codes = qt.q.astype(jnp.int32)
    w = codes.astype(jnp.float32) * qt.scale[..., None, :]
    return w.astype(dtype)


def take_columns(qt: QTensor, ids: jnp.ndarray) -> jnp.ndarray:
    """Gather-then-dequantize columns: (d_in, ids.shape...) fp32.

    Per-column scales make dequant∘gather ≡ gather∘dequant exactly, so this
    is the cheap form the ref/xla paths use for spec-head style gathers.
    """
    qcols = jnp.take(qt.q, ids, axis=-1)                 # (din', *ids)
    scols = jnp.take(qt.scale, ids, axis=-1)             # (*ids,)
    if qt.bits == 4:
        lo, hi = unpack_int4(jnp.moveaxis(qcols, 0, -1))
        codes = jnp.concatenate([lo, hi], axis=-1)       # (*ids, d_in)
        codes = jnp.moveaxis(codes, -1, 0)               # (d_in, *ids)
    else:
        codes = qcols.astype(jnp.int32)
    return codes.astype(jnp.float32) * scols[None]


# ---------------------------------------------------------------------------
# QuantSpec + params conversion
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """What to compress and how.

    ``bits`` applies to every selected tensor. Selection flags:
    ``lm_head`` — the verify/spec-head LM head (the per-token HBM hot spot);
    ``predictors`` — the stacked exit-predictor MLP bank;
    ``proj`` — per-layer attention/MLP projection matrices (weight-only:
    dequant happens inside the consumer jit, XLA fuses it into the matmul).
    MoE expert banks and norms/biases/embeddings are never quantized.
    """

    bits: int = 8
    lm_head: bool = True
    predictors: bool = True
    proj: bool = True

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"QuantSpec.bits must be 4 or 8, got {self.bits}")

    @classmethod
    def resolve(cls, spec) -> "QuantSpec":
        """Accept a QuantSpec, 'int8'/'int4', 8/4, or None (-> no quant)."""
        if spec is None or isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            name = spec.lower().lstrip("int")
            if name in ("8", "4"):
                return cls(bits=int(name))
            raise ValueError(f"unknown quant spec {spec!r} "
                             "(want 'int8' or 'int4')")
        if spec in (4, 8):
            return cls(bits=int(spec))
        raise ValueError(f"cannot resolve quant spec {spec!r}")


def _quantize_proj_subtree(p: Dict[str, Any], bits: int) -> Dict[str, Any]:
    """Parallel subtree of QTensors for the attn/mlp linear ``w`` leaves.

    Returns a nested dict mirroring ``p``'s paths but containing ONLY the
    quantized leaves — ``merge_dequant`` later grafts them back. Stacked
    segment leaves carry a leading (reps,) dim; QTensor handles it as a
    batch dim.
    """
    out: Dict[str, Any] = {}
    for unit_key, unit in p.items():
        got: Dict[str, Any] = {}
        for sub in ("attn", "mlp"):
            if sub not in unit:
                continue
            qsub = {}
            for name, lin in unit[sub].items():
                if isinstance(lin, dict) and "w" in lin and lin["w"].ndim >= 2:
                    qsub[name] = {"w": quantize_tensor(lin["w"], bits)}
            if qsub:
                got[sub] = qsub
        if got:
            out[unit_key] = got
    return out


def quantize_params(params: common.Params, sw, spec) -> Optional[Dict[str, Any]]:
    """Build the parallel quantized pytree for a params + SpecEE bundle.

    Returns ``{"lm_head": QTensor|None, "predictors": bank|None,
    "proj": [per-segment subtree]|None}`` — or None when ``spec`` is None.
    ``params`` and ``sw`` are read, never written; a tied LM head is
    materialized (embedding transpose) before quantization.
    """
    spec = QuantSpec.resolve(spec)
    if spec is None:
        return None
    qw: Dict[str, Any] = {"lm_head": None, "predictors": None, "proj": None}
    if spec.lm_head:
        qw["lm_head"] = quantize_tensor(common.lm_head_weight(params),
                                        spec.bits)
    if spec.predictors and sw is not None and sw.predictors is not None:
        layers = []
        for layer in sw.predictors["layers"]:
            layers.append({"w": quantize_tensor(layer["w"], spec.bits),
                           "b": layer["b"]})
        qw["predictors"] = {"layers": layers}
    if spec.proj:
        qw["proj"] = [_quantize_proj_subtree(seg, spec.bits)
                      for seg in params["segments"]]
    return qw


def merge_dequant(params: common.Params, qproj) -> common.Params:
    """Params view with projection leaves replaced by their dequantized
    copies (weight-only decoding: the int8/int4 codes are what lives in
    HBM; the dequant runs inside the same jit as the consumer matmul, so
    XLA fuses it and the fp weight never round-trips).
    """
    if qproj is None:
        return params

    def graft(dst, src):
        if isinstance(src, QTensor):
            return src.dequantize(dst.dtype if hasattr(dst, "dtype")
                                  else jnp.float32)
        out = dict(dst)
        for k, v in src.items():
            out[k] = graft(dst[k], v)
        return out

    segs = [graft(seg, qseg) if qseg else seg
            for seg, qseg in zip(params["segments"], qproj)]
    return dict(params, segments=segs)


def dequantized_reference(params: common.Params, sw, qw
                          ) -> Tuple[common.Params, Any]:
    """(params', sw') where every quantized tensor is replaced by its
    dequantized fp copy — the oracle the token-parity tests decode against:
    a plain (unquantized) engine on (params', sw') must emit exactly what a
    quantized engine on (params, sw, qw) emits.
    """
    p2 = merge_dequant(params, qw.get("proj"))
    if qw.get("lm_head") is not None:
        # explicit lm_head entry overrides a tied embedding transpose
        p2 = dict(p2, lm_head={"w": qw["lm_head"].dequantize()})
    sw2 = sw
    if qw.get("predictors") is not None and sw is not None:
        layers = [{"w": l["w"].dequantize(), "b": l["b"]}
                  for l in qw["predictors"]["layers"]]
        sw2 = sw._replace(predictors={"layers": layers})
    return p2, sw2
