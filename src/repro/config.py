"""Configuration system for the repro framework.

Plain frozen dataclasses (no external deps) describing:
  * ModelConfig    — architecture hyperparameters (one per assigned arch)
  * SpecEEConfig   — the paper's technique knobs (T1/T2/T3)
  * ShardingConfig — parallelism policy selection
  * TrainConfig    — optimizer/schedule/batching for training
  * ServeConfig    — serving engine knobs
  * RunConfig      — the top-level bundle the launcher consumes

Every assigned architecture ships as a module in ``repro.configs`` that returns a
fully-populated RunConfig; reduced "smoke" variants are derived mechanically via
``ModelConfig.smoke()`` so CPU tests never instantiate full-size weights.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds — the model zoo is assembled from these.
# ---------------------------------------------------------------------------
ATTN = "attention"            # global causal (or bidirectional for encoders) attention
LOCAL_ATTN = "local_attention"  # sliding-window attention
RGLRU = "rglru"               # Real-Gated LRU recurrence (RecurrentGemma)
SSD = "ssd"                   # Mamba2 state-space duality block

FAMILY_DENSE = "dense"
FAMILY_MOE = "moe"
FAMILY_VLM = "vlm"
FAMILY_AUDIO = "audio"
FAMILY_HYBRID = "hybrid"
FAMILY_SSM = "ssm"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    # d_ff of each expert (may differ from the dense d_ff field)
    expert_d_ff: int
    # jitter / load-balancing loss weight used in training
    router_aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) hyperparameters."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk_size: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU hyperparameters."""
    lru_width: Optional[int] = None       # defaults to d_model
    conv_kernel: int = 4
    window: int = 2048                    # local attention window for LOCAL_ATTN blocks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    # block pattern; if empty, num_layers × ATTN (or SSD for ssm family)
    block_pattern: Tuple[str, ...] = ()
    causal: bool = True         # False for encoder-only archs
    use_bias: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    activation: str = "silu"    # silu | gelu
    rope_theta: float = 10000.0
    gated_mlp: bool = True      # silu-gated 3-matrix MLP vs plain 2-matrix MLP
    tie_embeddings: bool = False
    max_seq_len: int = 524_288
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # modality frontend stub: "none" | "vision_patches" | "audio_frames"
    frontend: str = "none"
    frontend_tokens: int = 256  # patches/frames prepended by the stub
    dtype: str = "bfloat16"     # compute/weight dtype for dry-run & serving
    param_dtype: str = "float32"  # master weights for training

    # ----- derived -----
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.num_layers, (
                f"{self.name}: block_pattern len {len(self.block_pattern)} != "
                f"num_layers {self.num_layers}")
            return self.block_pattern
        kind = SSD if self.family == FAMILY_SSM else ATTN
        return tuple([kind] * self.num_layers)

    def is_decoder(self) -> bool:
        return self.causal

    def is_attention_free(self) -> bool:
        return all(b == SSD for b in self.blocks())

    def supports_long_context(self) -> bool:
        """True iff no block is quadratic in sequence length (global attention)."""
        return all(b in (SSD, RGLRU, LOCAL_ATTN) for b in self.blocks())

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim()
        n_mlp_mats = 3 if self.gated_mlp else 2
        total = self.vocab_size * d              # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d         # lm head
        for kind in self.blocks():
            if kind in (ATTN, LOCAL_ATTN):
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
                if self.moe is not None:
                    e = self.moe
                    total += e.num_experts * n_mlp_mats * d * e.expert_d_ff + d * e.num_experts
                else:
                    total += n_mlp_mats * d * self.d_ff
                total += 2 * d                   # two norms
            elif kind == RGLRU:
                w = (self.rglru.lru_width or d) if self.rglru else d
                # conv + in/out projections + gates (a, input gate)
                total += 2 * d * w + w * d + 2 * w * w + (self.rglru.conv_kernel if self.rglru else 4) * w
                if self.moe is not None:
                    e = self.moe
                    total += e.num_experts * n_mlp_mats * d * e.expert_d_ff
                else:
                    total += n_mlp_mats * d * self.d_ff
                total += 2 * d
            elif kind == SSD:
                s = self.ssm or SSMConfig()
                di = s.d_inner(d)
                nh = s.n_heads(d)
                # in_proj produces [z, x, B, C, dt]
                total += d * (2 * di + 2 * s.d_state + nh)
                total += s.conv_kernel * (di + 2 * s.d_state)
                total += di * d                  # out proj
                total += 2 * nh + d              # A_log, D, norm
        total += d                               # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        n_mlp_mats = 3 if self.gated_mlp else 2
        full_experts = self.num_layers * e.num_experts * n_mlp_mats * self.d_model * e.expert_d_ff
        active_experts = self.num_layers * e.num_experts_per_tok * n_mlp_mats * self.d_model * e.expert_d_ff
        return self.param_count() - full_experts + active_experts

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            # hybrids keep two full pattern units so multi-unit loop paths are
            # exercised; homogeneous stacks shrink to 4 layers
            num_layers=6 if self.block_pattern else min(self.num_layers, 4),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=0,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.num_heads else 0,
            max_seq_len=512,
            frontend_tokens=8 if self.frontend != "none" else self.frontend_tokens,
            dtype="float32",
        )
        # preserve GQA ratio shape: kv == heads (MHA) stays MHA; otherwise kv < heads
        if self.num_heads:
            if self.num_kv_heads == self.num_heads:
                kw["num_kv_heads"] = 4
            elif self.num_kv_heads == 1:
                kw["num_kv_heads"] = 1
            else:
                kw["num_kv_heads"] = 2
        if self.moe is not None:
            kw["moe"] = MoEConfig(num_experts=4,
                                  num_experts_per_tok=min(2, self.moe.num_experts_per_tok),
                                  expert_d_ff=128)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=32, conv_kernel=4,
                                  chunk_size=32)
        if self.rglru is not None:
            kw["rglru"] = RGLRUConfig(lru_width=128, conv_kernel=4, window=64)
        if self.block_pattern:
            # rebuild a short pattern with the same mix
            n = kw["num_layers"]
            pat = tuple(self.block_pattern[i % len(self.block_pattern)] for i in range(n))
            kw["block_pattern"] = pat
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# SpecEE technique configuration (paper defaults)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SpecEEConfig:
    enabled: bool = True
    num_speculative: int = 4          # k speculative tokens (paper: 4)
    predictor_hidden: int = 512       # MLP hidden dim (paper DSE optimum)
    predictor_layers: int = 2         # MLP depth (paper DSE optimum)
    exit_threshold: float = 0.5       # sigmoid threshold
    # T2: two-level scheduling
    schedule_enabled: bool = True
    online_window: int = 5            # circular queue length N (paper: 5 tokens)
    online_radius: int = 2            # ±radius layers (paper: ±2)
    offline_top_frac: float = 0.3     # fraction of layers kept by offline schedule
    # T3: speculative decoding + hyper-token mapping
    tree_depth: int = 3
    tree_branch: int = 3              # top-b expansion per node
    # draft model (EAGLE-style single-layer head)
    draft_layers: int = 1
    # KV/state propagation for skipped layers
    propagate_kv: bool = True

    def feature_dim(self) -> int:
        return 3 * self.num_speculative  # logits, local probs, prob variation


# ---------------------------------------------------------------------------
# Sharding / distribution
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingConfig:
    # policy: "tp_dp"   — weights replicated over data, TP over model (small archs)
    #         "tp2d"    — weights sharded over (data, model) 2-D (big archs)
    #         "fsdp_tp" — training: weights+opt sharded over data, TP over model
    policy: str = "tp_dp"
    # logical axis names
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str = "pod"
    # activation-checkpointing policy for training: "none"|"full"|"dots"
    remat: str = "full"
    # shard KV-cache sequence dim over model axis when kv_heads < model_parallelism
    kv_seq_shard: bool = True
    # gradient compression on cross-pod reductions
    grad_compression: bool = False


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatch: int = 0              # 0 = no accumulation
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"         # cosine | wsd | constant
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 128
    max_seq_len: int = 32768
    page_size: int = 128             # paged KV block size (repro.api.cache)
    max_new_tokens: int = 256
    greedy: bool = True
    temperature: float = 1.0
    # chunked (Sarathi-style) prefill admission: max prompt tokens the serving
    # scheduler runs per decode tick; 0 = blocking (whole-prompt) admission
    prefill_chunk: int = 512

    def __post_init__(self) -> None:
        if self.page_size <= 0:
            raise ValueError(
                f"ServeConfig.page_size must be > 0, got {self.page_size}")
        if self.max_seq_len % self.page_size:
            raise ValueError(
                f"ServeConfig.page_size ({self.page_size}) must divide "
                f"max_seq_len ({self.max_seq_len}) so pages tile the KV "
                "cache exactly")
        if self.prefill_chunk < 0:
            raise ValueError(
                "ServeConfig.prefill_chunk must be >= 0 (0 = blocking "
                f"admission), got {self.prefill_chunk}")


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    specee: SpecEEConfig = field(default_factory=SpecEEConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)

    def smoke(self) -> "RunConfig":
        return replace(
            self,
            model=self.model.smoke(),
            train=replace(self.train, global_batch=4, seq_len=32, steps=2,
                          microbatch=0, checkpoint_every=1),
            serve=replace(self.serve, max_batch=2, max_seq_len=128, page_size=16,
                          max_new_tokens=8, prefill_chunk=32),
        )


# ---------------------------------------------------------------------------
# Input shape cells (assigned shape set)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(model: ModelConfig) -> List[ShapeCell]:
    """Which of the four assigned shapes a given arch runs (skips per DESIGN.md §4)."""
    out: List[ShapeCell] = []
    for s in SHAPES:
        if s.kind == "decode" and not model.is_decoder():
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and not model.supports_long_context():
            continue  # quadratic attention: skip 500k
        out.append(s)
    return out
