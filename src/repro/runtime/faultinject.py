"""Deterministic fault injection for the serving stack.

A ``FaultSchedule`` names *sites* — places in the serving path that consult
the injector — and the visit indices at which each site should fail. Sites
are consulted with ``fire(site)`` (count the visit, return whether to
inject) or ``check(site)`` (raise ``InjectedFault``); an uninstalled
injector makes every site a no-op, so production code pays one global read
per consultation.

Named sites (the serving fault surface, DESIGN.md §7):

  * ``dispatch``        — a megatick dispatch raises before the jit call
                          (pre-donation, so the engine's backoff retry is
                          safe to re-issue against unchanged state);
  * ``finish_timeout``  — the watchdog declares an async megatick handle
                          wedged before its results are read (the results
                          are lost; the engine evicts + replays);
  * ``nan_logits``      — a megatick's emitted tokens are poisoned (the
                          argmax of NaN logits is garbage; the engine's
                          range validation catches it);
  * ``pool_exhausted``  — ``KVCacheManager.can_admit`` reports a dry pool,
                          driving the victim-eviction path;
  * ``sigterm``         — a preemption signal lands between serving ticks
                          (sets ``PreemptionGuard.requested``, exactly what
                          the real SIGTERM handler does);
  * ``device_lost``     — a device drops out of the engine's mesh between
                          serving ticks (deterministically the highest
                          device): the engine drains, consults
                          ``plan_replica_remesh``, and rebuilds at the
                          lower TP degree with verified replay — or raises
                          ``ServingFault(site="device_lost")`` when nothing
                          survives (the pool's kill-and-requeue fallback).

Schedules are deterministic: explicit visit sets (``FaultSchedule.at``,
``FaultSchedule.once``) or a seeded Bernoulli plan materialized up front
(``FaultSchedule.seeded``) — re-running the same schedule against the same
workload injects at exactly the same points, which is what makes the
token-parity acceptance test meaningful.

Keep this module dependency-light (stdlib + numpy): the cache manager and
the session consult it on hot-ish host paths.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import numpy as np

SITES = ("dispatch", "finish_timeout", "nan_logits", "pool_exhausted",
         "sigterm", "device_lost")


class InjectedFault(RuntimeError):
    """Raised by ``check`` at a firing site. Carries the site + visit so
    recovery code can branch on where the (synthetic) failure happened."""

    def __init__(self, site: str, visit: int):
        super().__init__(f"injected fault at site {site!r} (visit {visit})")
        self.site = site
        self.visit = visit


@dataclass(frozen=True)
class FaultSchedule:
    """site -> visit indices (0-based, per-site counters) that inject."""

    plan: Dict[str, FrozenSet[int]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        for site in self.plan:
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; expected one of {SITES}")

    @classmethod
    def once(cls, site: str, visit: int = 0) -> "FaultSchedule":
        """Inject at one site, one visit — the CI sweep's shape."""
        return cls({site: frozenset({visit})})

    @classmethod
    def at(cls, **site_visits: Iterable[int]) -> "FaultSchedule":
        """Explicit plan: ``FaultSchedule.at(pool_exhausted=range(8))``."""
        return cls({s: frozenset(int(v) for v in vs)
                    for s, vs in site_visits.items()})

    @classmethod
    def seeded(cls, seed: int, rate: float = 0.05,
               sites: Tuple[str, ...] = SITES,
               horizon: int = 256) -> "FaultSchedule":
        """Bernoulli(rate) per (site, visit) over ``horizon`` visits,
        materialized deterministically from ``seed``."""
        rng = np.random.default_rng(seed)
        plan = {}
        for site in sites:
            hits = np.nonzero(rng.random(horizon) < rate)[0]
            if hits.size:
                plan[site] = frozenset(int(v) for v in hits)
        return cls(plan)


class FaultInjector:
    """Counts visits per site against a schedule; records what fired."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.visits: Counter = Counter()
        self.fired: List[Tuple[str, int]] = []

    def fire(self, site: str) -> bool:
        v = self.visits[site]
        self.visits[site] = v + 1
        hit = v in self.schedule.plan.get(site, ())
        if hit:
            self.fired.append((site, v))
        return hit

    def check(self, site: str) -> None:
        if self.fire(site):
            raise InjectedFault(site, self.fired[-1][1])

    def fired_sites(self) -> FrozenSet[str]:
        return frozenset(s for s, _ in self.fired)


_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def install(schedule: FaultSchedule) -> FaultInjector:
    """Install a fresh injector for ``schedule`` (replacing any current one)
    and return it."""
    global _ACTIVE
    _ACTIVE = FaultInjector(schedule)
    return _ACTIVE


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def fire(site: str) -> bool:
    """Site entry point: False (and no visit counting) when no injector is
    installed."""
    inj = _ACTIVE
    return inj.fire(site) if inj is not None else False


def check(site: str) -> None:
    """Site entry point: raise ``InjectedFault`` if the site fires."""
    inj = _ACTIVE
    if inj is not None:
        inj.check(site)


@contextmanager
def injected(schedule: FaultSchedule):
    """``with faultinject.injected(FaultSchedule.once("dispatch")) as inj:``"""
    inj = install(schedule)
    try:
        yield inj
    finally:
        uninstall()
