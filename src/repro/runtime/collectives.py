"""Distributed-optimization primitives.

* int8 gradient compression with error feedback — for cross-pod (DCN-class)
  all-reduces where link bandwidth, not compute, bounds step time.
* overlapped collective matmul — all-gather-of-activations matmul where each
  ``ppermute`` hop overlaps with the partial GEMM of the shard already in
  hand (the "collective matmul" / Wang et al. decomposition). Used by the
  §Perf hillclimb as a beyond-paper optimization for TP layers.

Both are shard_map-level building blocks; GSPMD handles the default paths.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------
def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    error: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 all-reduce: participants agree on a SHARED scale
    (one scalar pmax), quantize (x+error), reduce the int8 payload (4-8× less
    link traffic than fp32/bf16), and keep the per-participant quantization
    residual locally for the next step. The int8 sum × shared scale is an
    UNBIASED estimate of the fp32 sum (error ≤ P·scale/2 elementwise, feedback
    absorbs it across steps). Call inside shard_map.
    Returns (reduced fp32, new local error)."""
    target = x.astype(jnp.float32) + error
    gmax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name) + 1e-12
    scale = gmax / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_error = target - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_error


# ---------------------------------------------------------------------------
# overlapped collective matmul (all-gather x GEMM pipelining)
# ---------------------------------------------------------------------------
def collective_matmul_ag(x_shard: jnp.ndarray, w: jnp.ndarray,
                         axis_name: str) -> jnp.ndarray:
    """Compute (all_gather(x) @ w) as a ppermute ring where each hop's
    transfer overlaps the GEMM on the shard already received.

    x_shard: (rows/P, K) local activation shard; w: (K, N) local weight
    (typically itself TP-sharded on N). Returns (rows, N) — the full product
    for this TP group, rows ordered by source rank.
    Called inside shard_map with ``axis_name`` a mesh axis of size P.
    """
    # jax<=0.4.x has no jax.lax.axis_size; psum(1) is the portable spelling
    if hasattr(jax.lax, "axis_size"):
        P_ = jax.lax.axis_size(axis_name)
    else:
        P_ = int(jax.lax.psum(1, axis_name))
    idx = jax.lax.axis_index(axis_name)
    rows = x_shard.shape[0]

    def step(i, carry):
        buf, out = carry
        # GEMM on the shard in hand — XLA schedules the next permute's DMA
        # concurrently because there is no data dependence between them.
        part = jnp.dot(buf, w, preferred_element_type=jnp.float32)
        src = (idx - i) % P_  # which rank's rows we just multiplied
        out = jax.lax.dynamic_update_slice_in_dim(
            out, part.astype(out.dtype), src * rows, axis=0)
        buf = jax.lax.ppermute(
            buf, axis_name,
            perm=[(j, (j + 1) % P_) for j in range(P_)])
        return buf, out

    out0 = jnp.zeros((rows * P_, w.shape[1]), x_shard.dtype)
    # mark the accumulator as device-varying along the ring axis (shard_map
    # VMA typing: the carry is written with per-device data every hop);
    # jax<=0.4.x has no VMA typing and no pvary — the constant carry is fine
    if hasattr(jax.lax, "pvary"):
        out0 = jax.lax.pvary(out0, (axis_name,))
    buf, out = jax.lax.fori_loop(0, P_, step, (x_shard, out0))
    return out
