"""Fault tolerance & elasticity for 1000+-node deployments.

What runs for real in this container vs. what is cluster-wired:

  * Checkpoint/restart       — REAL (repro.checkpoint): step-atomic shards,
    async save, restore_latest; the train loop resumes params/opt/data state.
  * Straggler mitigation     — REAL logic, simulated signal: per-step
    wall-time EWMA per host; hosts beyond ``straggler_sigma`` deviations are
    flagged for exclusion. On a cluster the signal is the per-host heartbeat
    stream; here tests inject synthetic timings.
  * Elastic re-mesh          — REAL logic: given a surviving device count,
    ``plan_remesh`` picks the largest valid (data, model) factorization that
    preserves the model-parallel degree (TP size is a correctness constraint;
    DP shrinks), and the launcher rebuilds shardings and restores the last
    checkpoint into the new topology (parameters are topology-independent in
    our checkpoint format).
  * Preemption detection     — cluster-wired: SIGTERM handler requests a
    final sync save (hooked in launch/train.py).
"""
from __future__ import annotations

import dataclasses
import math
import signal
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    n: int = 0


class StragglerMonitor:
    """Flags hosts whose step time drifts above the fleet EWMA."""

    def __init__(self, alpha: float = 0.2, sigma: float = 3.0,
                 min_samples: int = 8):
        self.alpha = alpha
        self.sigma = sigma
        self.min_samples = min_samples
        self.hosts: Dict[int, HostStats] = {}

    def record(self, host: int, step_time: float) -> None:
        st = self.hosts.setdefault(host, HostStats())
        st.ewma = (step_time if st.n == 0
                   else (1 - self.alpha) * st.ewma + self.alpha * step_time)
        st.n += 1

    def fleet_stats(self) -> Tuple[float, float]:
        """Robust (median, MAD) — a straggler must not inflate its own
        detection threshold, so location/scale are median-based."""
        vals = sorted(s.ewma for s in self.hosts.values()
                      if s.n >= self.min_samples)
        if len(vals) < 2:
            return 0.0, 0.0
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        return med, mad

    def stragglers(self) -> List[int]:
        med, mad = self.fleet_stats()
        if med == 0.0:
            return []
        floor = max(1.4826 * mad, 0.05 * med)  # MAD→σ, noise floor
        return [h for h, s in self.hosts.items()
                if s.n >= self.min_samples and
                s.ewma > med + self.sigma * floor]


def plan_remesh(alive_devices: int, model_parallel: int, pods: int = 1,
                pod_alive: Optional[Tuple[int, ...]] = None
                ) -> Optional[Tuple[int, ...]]:
    """Largest usable mesh after failures.

    Keeps the TP degree fixed (weights are laid out for it) and shrinks DP.
    Survivors are physically spread across pods — a TP group cannot straddle
    the inter-pod boundary — so the factorization is per-pod: each pod
    contributes ``⌊pod_alive/model⌋`` groups, and a rectangular multi-pod
    mesh keeps the pods that still hold at least one group at the MINIMUM
    surviving group count. ``pod_alive`` gives the exact per-pod survivor
    counts; without it survivors are assumed evenly spread (remainder on the
    leading pods). One surviving pod degrades to a single-pod (data, model)
    mesh; zero returns None — not even one TP group survives anywhere.
    """
    if pod_alive is None:
        base, extra = divmod(alive_devices, pods)
        pod_alive = tuple(base + (1 if p < extra else 0)
                          for p in range(pods))
    groups = [a // model_parallel for a in pod_alive]
    usable = [g for g in groups if g >= 1]
    if not usable:
        return None
    if len(pod_alive) > 1 and len(usable) > 1:
        return (len(usable), min(usable), model_parallel)
    return (max(groups), model_parallel)


def plan_replica_remesh(alive_devices: int,
                        model_parallel: int) -> Optional[int]:
    """Largest degraded TP degree ONE replica can rebuild to after losing
    devices from its mesh (serving remesh, DESIGN.md §10).

    A replica's mesh is (data=1, model): on device loss the engine keeps
    data pinned at 1 and walks the TP degree down from the current one.
    Candidates are divisors of the original degree — the Megatron layout
    re-splits evenly only at divisors — and each is accepted when
    ``plan_remesh`` validates a (data, model') factorization over the
    survivors. Returns the new degree (1 = unsharded), or None when no
    device survives — the kill-and-requeue fallback."""
    if alive_devices < 1:
        return None
    for tp in range(min(alive_devices, model_parallel), 0, -1):
        if model_parallel % tp:
            continue
        if plan_remesh(alive_devices, tp) is not None:
            return tp
    return None


class PreemptionGuard:
    """SIGTERM → request a final checkpoint before the scheduler kills us.

    One process can hold several guards (one per ServingEngine plus one per
    train loop): ``install`` is idempotent per guard (repeated installs keep
    exactly one handler instead of chaining a new wrapper each time), and
    ``uninstall`` restores the handler that was active before this guard's
    install, so guards nest and tear down cleanly.
    """

    def __init__(self):
        self.requested = False
        self._prev = None
        self._installed = False

    def install(self) -> None:
        if self._installed:
            return
        def handler(signum, frame):
            self.requested = True
            if callable(self._prev):
                self._prev(signum, frame)
        self._prev = signal.signal(signal.SIGTERM, handler)
        self._installed = True

    def uninstall(self) -> None:
        """Restore the pre-install SIGTERM handler. No-op if not installed."""
        if not self._installed:
            return
        prev = self._prev if self._prev is not None else signal.SIG_DFL
        signal.signal(signal.SIGTERM, prev)
        self._prev = None
        self._installed = False

    def should_save(self) -> bool:
        return self.requested
