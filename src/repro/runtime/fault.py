"""Fault tolerance & elasticity for 1000+-node deployments.

What runs for real in this container vs. what is cluster-wired:

  * Checkpoint/restart       — REAL (repro.checkpoint): step-atomic shards,
    async save, restore_latest; the train loop resumes params/opt/data state.
  * Straggler mitigation     — REAL logic, simulated signal: per-step
    wall-time EWMA per host; hosts beyond ``straggler_sigma`` deviations are
    flagged for exclusion. On a cluster the signal is the per-host heartbeat
    stream; here tests inject synthetic timings.
  * Elastic re-mesh          — REAL logic: given a surviving device count,
    ``plan_remesh`` picks the largest valid (data, model) factorization that
    preserves the model-parallel degree (TP size is a correctness constraint;
    DP shrinks), and the launcher rebuilds shardings and restores the last
    checkpoint into the new topology (parameters are topology-independent in
    our checkpoint format).
  * Preemption detection     — cluster-wired: SIGTERM handler requests a
    final sync save (hooked in launch/train.py).
"""
from __future__ import annotations

import dataclasses
import math
import signal
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    n: int = 0


class StragglerMonitor:
    """Flags hosts whose step time drifts above the fleet EWMA."""

    def __init__(self, alpha: float = 0.2, sigma: float = 3.0,
                 min_samples: int = 8):
        self.alpha = alpha
        self.sigma = sigma
        self.min_samples = min_samples
        self.hosts: Dict[int, HostStats] = {}

    def record(self, host: int, step_time: float) -> None:
        st = self.hosts.setdefault(host, HostStats())
        st.ewma = (step_time if st.n == 0
                   else (1 - self.alpha) * st.ewma + self.alpha * step_time)
        st.n += 1

    def fleet_stats(self) -> Tuple[float, float]:
        """Robust (median, MAD) — a straggler must not inflate its own
        detection threshold, so location/scale are median-based."""
        vals = sorted(s.ewma for s in self.hosts.values()
                      if s.n >= self.min_samples)
        if len(vals) < 2:
            return 0.0, 0.0
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        return med, mad

    def stragglers(self) -> List[int]:
        med, mad = self.fleet_stats()
        if med == 0.0:
            return []
        floor = max(1.4826 * mad, 0.05 * med)  # MAD→σ, noise floor
        return [h for h, s in self.hosts.items()
                if s.n >= self.min_samples and
                s.ewma > med + self.sigma * floor]


def plan_remesh(alive_devices: int, model_parallel: int,
                pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest usable mesh after failures.

    Keeps the TP degree fixed (weights are laid out for it) and shrinks DP:
    usable = pods × data' × model with data' = ⌊alive/(pods·model)⌋.
    Returns the new mesh shape or None if not even one TP group survives.
    """
    per_pod = alive_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        # degrade: drop to single pod before giving up
        if pods > 1:
            return plan_remesh(alive_devices, model_parallel, pods=1)
        return None
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


class PreemptionGuard:
    """SIGTERM → request a final checkpoint before the scheduler kills us.

    One process can hold several guards (one per ServingEngine plus one per
    train loop): ``install`` is idempotent per guard (repeated installs keep
    exactly one handler instead of chaining a new wrapper each time), and
    ``uninstall`` restores the handler that was active before this guard's
    install, so guards nest and tear down cleanly.
    """

    def __init__(self):
        self.requested = False
        self._prev = None
        self._installed = False

    def install(self) -> None:
        if self._installed:
            return
        def handler(signum, frame):
            self.requested = True
            if callable(self._prev):
                self._prev(signum, frame)
        self._prev = signal.signal(signal.SIGTERM, handler)
        self._installed = True

    def uninstall(self) -> None:
        """Restore the pre-install SIGTERM handler. No-op if not installed."""
        if not self._installed:
            return
        prev = self._prev if self._prev is not None else signal.SIG_DFL
        signal.signal(signal.SIGTERM, prev)
        self._prev = None
        self._installed = False

    def should_save(self) -> bool:
        return self.requested
