"""Offline predictor training (paper §7.4.4) + offline exit statistics (§5.3).

The paper's recipe:
  * run the frozen LLM over prompts, collecting at every exit point the
    12-dim speculation features and a binary label — does the *early* global
    argmax at this layer equal the *final* (last-layer) argmax?
  * train one small MLP per exit point (minutes of work; ~16K samples/layer;
    ~2% of the data already reaches good accuracy — Fig. 18);
  * histogram where exits happen → the T2 offline schedule.

Everything runs on the reduced smoke configs in tests/examples; the same code
scales to real checkpoints (it is jit-compiled and batched).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig, SpecEEConfig
from repro.core import draft as draft_lib
from repro.core import features as feat_lib
from repro.core import predictor as pred_lib
from repro.core import scheduler as sched_lib
from repro.models.common import Params, lm_head_weight
from repro.models.model import Model


class FeatureDataset(NamedTuple):
    features: jnp.ndarray   # (E, T, 3k)
    labels: jnp.ndarray     # (E, T) float32 {0, 1}


@partial(jax.jit, static_argnums=(0,))
def _collect_batch(model: Model, params: Params, draft_params: Params,
                   tokens: jnp.ndarray) -> FeatureDataset:
    """Teacher-forced feature collection over a token batch.

    For every position t and exit point e: features from the hidden state
    after unit e, label = [argmax(LM head at e) == argmax(LM head at final)].
    The speculative set is the draft's top-k at each position, exactly as at
    inference time.
    """
    spec = model.run.specee
    k = spec.num_speculative
    lm_w = lm_head_weight(params)
    B, S = tokens.shape

    h = model.embed(params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    # per-unit hidden states: rerun forward, capturing after each unit
    hs: List[jnp.ndarray] = []
    for seg, (unit, reps) in enumerate(model.segments):
        def body(hc, unit_params):
            from repro.models.model import _block_seq
            for i, kind in enumerate(unit):
                hc, _, _ = _block_seq(model.cfg, kind, unit_params[f"u{i}"],
                                      hc, positions, model.flags, False)
            return hc, hc
        h, h_stack = jax.lax.scan(body, h, params["segments"][seg])
        hs.append(h_stack)                                  # (reps, B, S, D)
    h_units = jnp.concatenate(hs, axis=0)                   # (E, B, S, D)
    E = h_units.shape[0]

    # draft speculative tokens for every position (teacher-forced), with the
    # decode-consistent pairing: position t fuses (embed(tokens[t]), h[t-1])
    emb = model.embed(params, tokens)
    hd = draft_lib.draft_forward_seq(model.cfg, draft_params, emb,
                                     draft_lib.shift_hidden(h_units[-1]))
    dlogits = model.logits(params, hd)                      # (B, S, V)
    _, spec_ids = jax.lax.top_k(dlogits, k)
    spec_ids = spec_ids.astype(jnp.int32)                   # (B, S, k)

    # final-layer greedy target
    final_logits = model.logits(params, h_units[-1])        # (B, S, V)
    final_tok = jnp.argmax(final_logits, axis=-1)

    flat_ids = spec_ids.reshape(B * S, k)

    def per_unit(carry, h_e):
        prev = carry                                        # (B*S, k)
        hn = model.final_norm(params, h_e).reshape(B * S, -1)
        feats, probs = feat_lib.extract_features(hn, lm_w, flat_ids, prev)
        glog = (model.final_norm(params, h_e) @
                lm_w.astype(h_e.dtype)).astype(jnp.float32)
        gtok = jnp.argmax(glog, axis=-1)
        label = (gtok == final_tok).reshape(B * S).astype(jnp.float32)
        return probs, (feats, label)

    prev0 = jnp.full((B * S, k), 1.0 / k, jnp.float32)
    _, (feats, labels) = jax.lax.scan(per_unit, prev0, h_units)
    return FeatureDataset(features=feats, labels=labels)    # (E,T,3k),(E,T)


def collect_dataset(model: Model, params: Params, draft_params: Params,
                    token_batches: List[jnp.ndarray]) -> FeatureDataset:
    parts = [_collect_batch(model, params, draft_params, tb)
             for tb in token_batches]
    return FeatureDataset(
        features=jnp.concatenate([p.features for p in parts], axis=1),
        labels=jnp.concatenate([p.labels for p in parts], axis=1))


# ---------------------------------------------------------------------------
# training loop (Adam on stacked predictors — all exit points in parallel)
# ---------------------------------------------------------------------------
def train_predictors(spec: SpecEEConfig, data: FeatureDataset, key,
                     steps: int = 300, lr: float = 1e-3, batch: int = 256,
                     pos_weight: float = 1.0
                     ) -> Tuple[Params, Dict[str, float]]:
    E, T, F = data.features.shape
    params = pred_lib.init_predictors(spec, E, key)

    def loss_fn(p, feats, labels):
        # feats: (E, b, F); labels: (E, b)
        probs = jax.vmap(pred_lib.apply_predictor)(p, feats)
        eps = 1e-6
        bce = -(pos_weight * labels * jnp.log(probs + eps) +
                (1 - labels) * jnp.log(1 - probs + eps))
        return jnp.mean(bce)

    # Adam state
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(x) for x in flat]
    v = [jnp.zeros_like(x) for x in flat]

    @jax.jit
    def step(params, m, v, i, feats, labels):
        m_t = jax.tree_util.tree_unflatten(tree, m)
        v_t = jax.tree_util.tree_unflatten(tree, v)
        g = jax.grad(loss_fn)(params, feats, labels)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m_t = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m_t, g)
        v_t = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b,
                                     v_t, g)
        mhat = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** (i + 1)), m_t)
        vhat = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** (i + 1)), v_t)
        params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
            params, mhat, vhat)
        return (params, jax.tree_util.tree_leaves(m_t),
                jax.tree_util.tree_leaves(v_t))

    rng = np.random.default_rng(0)
    for i in range(steps):
        idx = rng.integers(0, T, size=(batch,))
        feats = data.features[:, idx, :]
        labels = data.labels[:, idx]
        params, m, v = step(params, m, v, i, feats, labels)

    # metrics on the full set
    probs = jax.vmap(pred_lib.apply_predictor)(params, data.features)
    pred = (probs > spec.exit_threshold).astype(jnp.float32)
    acc = float(jnp.mean((pred == data.labels).astype(jnp.float32)))
    pos_rate = float(jnp.mean(data.labels))
    return params, {"accuracy": acc, "positive_rate": pos_rate}


# ---------------------------------------------------------------------------
# offline exit statistics -> T2 offline schedule
# ---------------------------------------------------------------------------
def offline_exit_counts(model: Model, params: Params, sw, token_batches,
                        max_new: int = 16) -> np.ndarray:
    """Run AR SpecEE decoding with ALL predictors active and histogram where
    exits occur (paper Fig. 10)."""
    import dataclasses

    from repro.api import SpecEEStrategy
    E = model.num_exit_points
    counts = np.zeros(E + 1, np.int64)
    spec_all = dataclasses.replace(model.run.specee, schedule_enabled=False)
    model_all = type(model)(dataclasses.replace(model.run, specee=spec_all),
                            model.flags)
    strat = SpecEEStrategy()
    for tokens in token_batches:
        B, T = tokens.shape
        first, st = strat.init_state(model_all, params, sw,
                                     {"tokens": tokens}, T + max_new + 1)
        for _ in range(max_new):
            res, st = strat.step(model_all, params, sw, st)
            pts = np.asarray(jnp.minimum(res.exit_layer, E))
            for p in pts:
                counts[p] += 1
    return counts
