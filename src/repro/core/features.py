"""T1 — speculation features (paper §4.3.1).

Three features per speculative token, k=4 tokens -> 12-dim input:
  (1) speculative token logits  — h·lm_head[:, spec_ids], the (1×D)·(D×k) GEMM
  (2) local probabilities       — softmax over the k logits
  (3) probability variation     — local probs minus previous layer's

The (D×k) gather-GEMM is the hot spot the paper's custom operator targets; the
Pallas TPU version lives in ``repro.kernels.spec_head`` and is selected with
``use_kernel=True`` (identical numerics, fused gather+GEMM+softmax+Δ).

The AR decode engine no longer stops at the features: with
``ModelFlags.exit_gate_kernel`` the whole feature→predictor→verify chain runs
through ``repro.kernels.exit_gate`` in one fused pipeline. This module stays
the feature-level building block for the tree path (whose hyper-token merge
sits between features and predictor) and for predictor training.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, lm_head_weight


def spec_logits_ref(hn: jnp.ndarray, lm_head,
                    spec_ids: jnp.ndarray) -> jnp.ndarray:
    """hn: (B, D) final-normed hidden; lm_head: (D, V) array or a quantized
    ``repro.quant.QTensor``; spec_ids: (B, k).

    Returns (B, k) fp32 logits — reference implementation of the speculative
    LM head (columns of the LM head gathered per row). For a quantized head
    the columns are gathered then dequantized — identical to dequantizing
    first because the scales are per-output-column.
    """
    if hasattr(lm_head, "bits"):                      # QTensor
        from repro.quant import take_columns
        cols = take_columns(lm_head, spec_ids)        # (D, B, k) fp32
    else:
        cols = jnp.take(lm_head, spec_ids, axis=1)    # (D, B, k)
    cols = jnp.moveaxis(cols, 1, 0)                   # (B, D, k)
    return jnp.einsum("bd,bdk->bk", hn.astype(jnp.float32),
                      cols.astype(jnp.float32))


def extract_features(hn: jnp.ndarray, lm_head,
                     spec_ids: jnp.ndarray, prev_probs: jnp.ndarray,
                     use_kernel: bool = False
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute the 3k feature vector for one exit point.

    hn: (B, D) — final-normed hidden state of the current layer
    prev_probs: (B, k) — local probabilities at the previous exit point
    Returns (features (B, 3k) fp32, local_probs (B, k) fp32).
    """
    if use_kernel:
        from repro.kernels.spec_head import ops as sh_ops
        logits, probs = sh_ops.spec_head(hn, lm_head, spec_ids)
    else:
        logits = spec_logits_ref(hn, lm_head, spec_ids)
        probs = jax.nn.softmax(logits, axis=-1)
    variation = probs - prev_probs
    feats = jnp.concatenate([logits, probs, variation], axis=-1)
    return feats, probs


def merge_path_features(node_feats: jnp.ndarray, node_probs: jnp.ndarray,
                        path_nodes: jnp.ndarray, path_len: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """T3 — hyper-token feature merge (paper §6.2, Cannikin law).

    node_feats: (B, N, 3k) per-node features; node_probs: (B, N, k);
    path_nodes: (P, Dmax) int32 node indices per path (-1 padded);
    path_len:   (P,) int32.

    A path exits only when its *weakest* node would exit, so the merged
    feature is the elementwise minimum over the path's nodes — one predictor
    evaluation per path (linear in #paths instead of exponential per-node
    mapping). Returns (path_feats (B, P, 3k), path_probs (B, P, k)).
    """
    P, Dmax = path_nodes.shape
    safe = jnp.maximum(path_nodes, 0)                          # (P, Dmax)
    gathered = node_feats[:, safe, :]                          # (B, P, Dmax, 3k)
    gp = node_probs[:, safe, :]                                # (B, P, Dmax, k)
    valid = (path_nodes >= 0)[None, :, :, None]
    big = jnp.float32(1e30)
    merged = jnp.min(jnp.where(valid, gathered, big), axis=2)
    merged_p = jnp.min(jnp.where(valid, gp, big), axis=2)
    return merged, merged_p
