"""EAGLE-style draft (speculative) model — the DLM (paper §2.2, §3.2).

One decoder layer operating at the target model's ``d_model``, fed with the
fusion of (embedding of the current token, target hidden state at the current
position) — EAGLE's "feature uncertainty" recipe. The TLM's embedding matrix
and LM head are reused, so the DLM adds ~(2D·D + one block) parameters (~3% of
a 7B model — the paper's memory claim).

The draft keeps its own single-layer KV cache so it can extend speculations
autoregressively (tree expansion) without re-reading the context.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import dataclasses
import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig, SpecEEConfig
from repro.models import attention as attn_lib
from repro.models import common
from repro.models.common import KeyGen, Params


def _draft_cfg(cfg: ModelConfig) -> ModelConfig:
    """The draft layer reuses the target's geometry but is always 1 layer."""
    kv = cfg.num_kv_heads if cfg.num_kv_heads > 0 else 4
    heads = cfg.num_heads if cfg.num_heads > 0 else 4
    return dataclasses.replace(
        cfg, num_layers=1, num_heads=heads, num_kv_heads=kv,
        head_dim=cfg.resolved_head_dim() or cfg.d_model // heads,
        block_pattern=(), causal=True, moe=None)


def init_draft(cfg: ModelConfig, key) -> Params:
    dc = _draft_cfg(cfg)
    kg = KeyGen(key)
    d = cfg.d_model
    dc = dataclasses.replace(dc, d_ff=cfg.d_ff if cfg.d_ff > 0 else 4 * d)
    return {
        "fuse": common.init_linear(kg, 2 * d, d, True),
        "ln1": common.init_norm(dc, d),
        "attn": attn_lib.init_attention(dc, kg),
        "ln2": common.init_norm(dc, d),
        "mlp": common.init_mlp(dc, kg),
    }


def draft_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Any:
    dc = _draft_cfg(cfg)
    hd = dc.resolved_head_dim()
    shape = (batch, max_seq, dc.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _fused_input(cfg: ModelConfig, p: Params, embed_tok: jnp.ndarray,
                 h_target: jnp.ndarray) -> jnp.ndarray:
    x = jnp.concatenate([embed_tok, h_target.astype(embed_tok.dtype)], axis=-1)
    return common.apply_linear(p["fuse"], x)


def _pos_col(pos, B: int) -> jnp.ndarray:
    """Broadcast a scalar or (B,) position to a (B, 1) int32 column."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((B, 1), pos, jnp.int32)
    return pos[:, None]


def draft_step(cfg: ModelConfig, p: Params, embed_tok: jnp.ndarray,
               h_target: jnp.ndarray, cache: Any, pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, Any]:
    """One draft forward. embed_tok, h_target: (B, D); pos: scalar or (B,)
    int32 — position this step writes. Returns (h_draft (B, D), new cache)."""
    dc = _draft_cfg(cfg)
    B = embed_tok.shape[0]
    h = _fused_input(cfg, p, embed_tok, h_target)              # (B, D)
    x = common.apply_norm(dc, p["ln1"], h)[:, None, :]
    positions = _pos_col(pos, B)
    q, k, v = attn_lib.qkv(dc, p["attn"], x, positions)
    rows = jnp.arange(B)
    pvec = positions[:, 0]
    k_cache = cache["k"].at[rows, pvec].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, pvec].set(v[:, 0].astype(cache["v"].dtype))
    o = attn_lib.attend_decode(dc, q, k_cache, v_cache, pvec + 1)
    h = h + attn_lib.out_proj(p["attn"], o)[:, 0, :]
    x2 = common.apply_norm(dc, p["ln2"], h[:, None, :])
    h = h + common.apply_mlp(dc, p["mlp"], x2)[:, 0, :]
    return h, {"k": k_cache, "v": v_cache}


def draft_step_readonly(cfg: ModelConfig, p: Params, embed_tok: jnp.ndarray,
                        h_parent: jnp.ndarray, cache: Any, pos: jnp.ndarray,
                        cache_len: jnp.ndarray) -> jnp.ndarray:
    """Tree-expansion draft forward that does NOT mutate the cache: the node
    attends the trunk context plus itself; parent information flows through
    the fused ``h_parent`` input (EAGLE feature chaining). Supports a batch of
    nodes: embed_tok/h_parent: (B*, D) where B* = batch × nodes-at-level."""
    dc = _draft_cfg(cfg)
    B = embed_tok.shape[0]
    h = _fused_input(cfg, p, embed_tok, h_parent)
    x = common.apply_norm(dc, p["ln1"], h)[:, None, :]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1 and pos.shape[0] != B:     # per-row pos over node groups
        pos = jnp.repeat(pos, B // pos.shape[0])
    positions = _pos_col(pos, B)
    q, k, v = attn_lib.qkv(dc, p["attn"], x, positions)
    # context attention (cache may be batch-1-broadcastable over nodes)
    kc, vc = cache["k"], cache["v"]
    if kc.shape[0] != B:
        reps = B // kc.shape[0]
        kc = jnp.repeat(kc, reps, axis=0)
        vc = jnp.repeat(vc, reps, axis=0)
    n_rep = dc.num_heads // dc.num_kv_heads
    kk = attn_lib._repeat_kv(kc, n_rep)
    vv = attn_lib._repeat_kv(vc, n_rep)
    # append self k/v without writing the cache
    kk_self = attn_lib._repeat_kv(k, n_rep)
    vv_self = attn_lib._repeat_kv(v, n_rep)
    kk = jnp.concatenate([kk, kk_self.astype(kk.dtype)], axis=1)
    vv = jnp.concatenate([vv, vv_self.astype(vv.dtype)], axis=1)
    S = kc.shape[1]
    kpos = jnp.arange(S + 1)[None, :]
    clen = jnp.reshape(cache_len, (-1, 1))
    if clen.shape[0] not in (1, B):  # (batch,) broadcast over nodes
        clen = jnp.repeat(clen, B // clen.shape[0], axis=0)
    valid = (kpos < clen) | (kpos == S)
    mask = valid[:, None, None, :]
    o = attn_lib.sdpa(q, kk, vv, mask)
    h = h + attn_lib.out_proj(p["attn"], o)[:, 0, :]
    x2 = common.apply_norm(dc, p["ln2"], h[:, None, :])
    h = h + common.apply_mlp(dc, p["mlp"], x2)[:, 0, :]
    return h


def shift_hidden(h: jnp.ndarray) -> jnp.ndarray:
    """h[:, t] -> h[:, t-1] with zeros at t=0 (decode-consistent pairing:
    the draft for the token at position t fuses the hidden of t-1)."""
    return jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def draft_forward_seq(cfg: ModelConfig, p: Params, embeds: jnp.ndarray,
                      h_prev: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced full-sequence draft forward (training / collection).

    embeds: (B, S, D) token embeddings at position t; h_prev: (B, S, D) target
    hidden of position t-1 (use ``shift_hidden``). Returns draft hidden
    (B, S, D) whose LM-head logits propose the token at t+1."""
    dc = _draft_cfg(cfg)
    B, S, D = embeds.shape
    x = jnp.concatenate([embeds, h_prev.astype(embeds.dtype)], axis=-1)
    h = common.apply_linear(p["fuse"], x)
    xn = common.apply_norm(dc, p["ln1"], h)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = attn_lib.qkv(dc, p["attn"], xn, positions)
    o = attn_lib.attend_full(dc, q, k, v)
    h = h + attn_lib.out_proj(p["attn"], o)
    x2 = common.apply_norm(dc, p["ln2"], h)
    return h + common.apply_mlp(dc, p["mlp"], x2)


def draft_prefill(cfg: ModelConfig, p: Params, embeds: jnp.ndarray,
                  h_targets: jnp.ndarray, max_seq: int) -> Any:
    """Build the draft cache over a prompt. embeds/h_targets: (B, S, D).
    h_targets are the SAME-position hiddens; the cache stores K/V of the
    decode-consistent fused inputs (shifted internally)."""
    dc = _draft_cfg(cfg)
    B, S, D = embeds.shape
    x = jnp.concatenate([embeds, shift_hidden(h_targets).astype(embeds.dtype)],
                        axis=-1)
    h = common.apply_linear(p["fuse"], x)
    xn = common.apply_norm(dc, p["ln1"], h)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = attn_lib.qkv(dc, p["attn"], xn, positions)
    pad = [(0, 0), (0, max_seq - S), (0, 0), (0, 0)]
    return {"k": jnp.pad(k, pad).astype(embeds.dtype),
            "v": jnp.pad(v, pad).astype(embeds.dtype)}


def propose_topk(model, params: Params, h_draft: jnp.ndarray,
                 k: int, lm_w=None, shard=None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Draft hidden -> top-k speculative token ids via the TLM's LM head.

    Streams the vocab through ``exit_gate.ops.verify_topk`` (the top-k
    sibling of the argmax-verify kernel), so the fused-gate path never
    materializes the (B, V) draft logits either; with the flag off the
    "ref" impl reproduces the historical ``model.logits`` + ``top_k``
    bit-for-bit. ``lm_w`` overrides the LM head — a ``repro.quant.QTensor``
    here routes the proposal through the quantized verify kernels.
    ``shard``: optional ShardCtx — the proposal becomes a per-shard partial
    top-k over the local vocab slice (token-identical merge; DESIGN.md §9).
    Returns (spec_ids (B, k) int32, spec_logits (B, k) fp32).
    """
    from repro.kernels.exit_gate import ops as gate_lib
    hn = model.final_norm(params, h_draft)
    if lm_w is None:
        lm_w = common.lm_head_weight(params)
    ids, vals = gate_lib.verify_topk(
        hn, lm_w, k, impl=gate_lib.impl_for_flags(model.flags), shard=shard)
    return ids, vals


def draft_param_count(cfg: ModelConfig) -> int:
    p = init_draft(cfg, jax.random.PRNGKey(0))
    return sum(x.size for x in jax.tree_util.tree_leaves(p))
