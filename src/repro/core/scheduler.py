"""T2 — two-level heuristic predictor scheduling (paper §5).

Offline level: exit frequencies follow a skewed distribution (paper Fig. 10:
bottom-50% layers carry <20% of exits). A one-time offline pass histograms
exit points; the top ``offline_top_frac`` fraction becomes a static boolean
mask baked into the model's run configuration.

Online level: context similarity (paper Fig. 11: the exit layer of the current
token lies within ±2 of the last 5 tokens' exit layers with ~80% probability).
A circular queue of the last N exit points is maintained per sequence; the
active set is (offline mask) ∪ (±radius neighbourhoods of queued exits).

Everything is a pytree of arrays so it lives inside jitted decode loops.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import SpecEEConfig

SchedState = Dict[str, jnp.ndarray]


def init_state(batch: int, spec: SpecEEConfig) -> SchedState:
    return {
        "queue": jnp.full((batch, spec.online_window), -1, jnp.int32),
        "qpos": jnp.zeros((batch,), jnp.int32),
    }


def offline_mask_from_counts(counts: jnp.ndarray,
                             spec: SpecEEConfig) -> jnp.ndarray:
    """counts: (E,) exit-frequency histogram -> (E,) bool top-fraction mask."""
    E = counts.shape[0]
    keep = max(1, int(round(spec.offline_top_frac * E)))
    order = jnp.argsort(-counts, stable=True)
    mask = jnp.zeros((E,), bool).at[order[:keep]].set(True)
    return mask


def active_mask(state: SchedState, offline: jnp.ndarray,
                spec: SpecEEConfig, num_exit_points: int) -> jnp.ndarray:
    """-> (B, E) bool: which exit points run a predictor for each row.

    If scheduling is disabled, every exit point is active (T1-only mode).
    """
    B = state["queue"].shape[0]
    if not spec.schedule_enabled:
        return jnp.ones((B, num_exit_points), bool)
    pts = jnp.arange(num_exit_points)[None, None, :]           # (1,1,E)
    q = state["queue"][:, :, None]                             # (B,N,1)
    near = (jnp.abs(pts - q) <= spec.online_radius) & (q >= 0)
    online = jnp.any(near, axis=1)                             # (B,E)
    return online | offline[None, :]


def update(state: SchedState, exit_point: jnp.ndarray) -> SchedState:
    """Push each row's exit point into its circular queue. exit_point: (B,)."""
    B, N = state["queue"].shape
    rows = jnp.arange(B)
    queue = state["queue"].at[rows, state["qpos"]].set(exit_point.astype(jnp.int32))
    return {"queue": queue, "qpos": (state["qpos"] + 1) % N}


def expected_active_count(state: SchedState, offline: jnp.ndarray,
                          spec: SpecEEConfig, num_exit_points: int) -> jnp.ndarray:
    """Average number of active predictors per row (paper: ~10.2 on Llama2-7B)."""
    return jnp.mean(jnp.sum(active_mask(state, offline, spec, num_exit_points)
                            .astype(jnp.float32), axis=-1))
