"""SpecEE decode engines (paper Fig. 3 dataflow) — the jittable
kernels-of-record beneath the unified decode API.

Application code decodes through ``repro.api`` (Engine / DecodeSession /
StepResult with pluggable strategies — see docs/api.md); the step functions
here are the pure computations those strategies adapt, and the only
sanctioned direct callers are ``repro/api`` and the tests.

``ar_decode_step``  — autoregressive decoding with speculative early exiting:
    draft k speculative tokens → layer-by-layer ``lax.while_loop`` with the
    T1 predictor at T2-scheduled exit points → verification (full LM head at
    the candidate exit layer; exit iff global argmax ∈ speculative set) →
    KV/state propagation for skipped layers.

``tree_decode_step`` — T3: EAGLE-style tree speculative decoding with the
    context-aware merged (hyper-token) mapping; one predictor evaluation per
    root→leaf path, exit at the rearmost (Cannikin) layer, acceptance by
    greedy path matching at the exit layer.

Per-exit-point decisions flow through the fused exit-gate entry points
(``repro.kernels.exit_gate.ops``), selected by ``ModelFlags.exit_gate_kernel``:
the AR gate (spec-head features + predictor) runs as ONE Pallas kernel and
verification/emit streams the LM head (running argmax, no (B, V) logits).
The tree gate fuses its three stages piecewise — spec-head feature kernel,
banked predictor-MLP kernel, streaming node verify — because the hyper-token
min-merge sits between features and predictor (folding the merge into the
gate kernel is a ROADMAP follow-on). With the flag off the same entry points
pin the historical four-op reference sequence bit-for-bit.

Semantics guarantees (property-tested in tests/):
  * with the predictor disabled (threshold > 1) the emitted tokens are
    bit-identical to dense greedy decoding. Caveat: the fused verify
    accumulates logits in fp32; with bf16 weights on TPU a near-exact tie in
    the top-2 logits may therefore resolve differently than the bf16 dense
    matmul (a numerics improvement, exercised only when the fused flag is on);
  * when a row exits, its emitted token equals argmax of the FULL LM head at
    the exit layer (verification), and is a member of the speculative set.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig, SpecEEConfig
from repro.core import draft as draft_lib
from repro.core import features as feat_lib
from repro.core import predictor as pred_lib
from repro.core import scheduler as sched_lib
from repro.kernels.exit_gate import ops as gate_lib
from repro.models import common
from repro.models.common import Params, lm_head_weight
from repro.models.model import Model


def _apply_qw(params: Params, sw: Optional["SpecEEWeights"], qw):
    """Resolve one step's weight views under an optional quantized bundle
    (the ``repro.quant.quantize_params`` output, threaded down from the API
    layer as an extra jit argument).

    Returns ``(params', lm_w, predictors)``: ``params'`` has quantized
    projection leaves replaced by dequantized views (weight-only — XLA fuses
    the dequant into the consuming matmul), ``lm_w`` is the ``QTensor`` LM
    head when quantized (the exit-gate / spec-head ops dispatch on the type
    and keep the int tiles resident) else the fp ``lm_head_weight``, and
    ``predictors`` the quantized bank when present. The original ``params``
    and ``sw`` pytrees are never touched — the bundle is a parallel tree.
    """
    predictors = sw.predictors if sw is not None else None
    if not qw:
        return params, lm_head_weight(params), predictors
    from repro import quant as quant_lib
    if qw.get("proj") is not None:
        params = quant_lib.merge_dequant(params, qw["proj"])
    lm_w = qw.get("lm_head")
    if lm_w is None:
        lm_w = lm_head_weight(params)
    if qw.get("predictors") is not None:
        predictors = qw["predictors"]
    return params, lm_w, predictors


def _gate_impls(model: Model) -> Tuple[str, bool]:
    """Exit-gate backend selection for a model's flags.

    Returns (impl for ``gate_lib.exit_gate``/``verify_argmax``, fused?).
    With ``exit_gate_kernel`` off the engine still flows through the same
    entry points, pinned to the "ref" impl — the historical four-op sequence,
    bit-for-bit (this is the numerics reference the fused path is property-
    tested against). Resolution lives in ``gate_lib.impl_for_flags`` so the
    decode strategies (repro.api) share the exact same selection.
    """
    fused = getattr(model.flags, "exit_gate_kernel", False)
    return gate_lib.impl_for_flags(model.flags), fused


class SpecEEWeights(NamedTuple):
    """Everything SpecEE adds next to the frozen target model."""
    draft: Params
    predictors: Params          # stacked over exit points
    offline_mask: jnp.ndarray   # (E,) bool — T2 offline schedule


class DecodeState(NamedTuple):
    cache: Any                  # target model cache (segments + len)
    draft_cache: Any
    sched: Dict[str, jnp.ndarray]
    last_token: jnp.ndarray     # (B,)
    h_last: jnp.ndarray         # (B, D) final hidden at the last position
    prng: jnp.ndarray


class StepInfo(NamedTuple):
    exit_point: jnp.ndarray     # (B,) unit index at exit (E if ran full depth)
    exited: jnp.ndarray         # (B,) bool — predictor-driven exit happened
    units_run: jnp.ndarray      # () int32 — units the while loops executed
    spec_hit: jnp.ndarray       # (B,) bool — final token ∈ speculative set


def init_specee(model: Model, key) -> SpecEEWeights:
    spec = model.run.specee
    k1, k2 = jax.random.split(key)
    return SpecEEWeights(
        draft=draft_lib.init_draft(model.cfg, k1),
        predictors=pred_lib.init_predictors(spec, model.num_exit_points, k2),
        offline_mask=jnp.ones((model.num_exit_points,), bool),
    )


def init_decode_state(model: Model, params: Params,
                      sw: Optional[SpecEEWeights],
                      batch: Dict[str, jnp.ndarray], max_seq: int,
                      prng=None) -> Tuple[jnp.ndarray, DecodeState]:
    """Prefill the target + draft and build the decode state.

    ``sw=None`` builds a dense-only state (no draft cache) — only
    ``dense_decode_step`` may consume it. Returns (first greedy token (B,),
    state)."""
    spec = model.run.specee
    logits, cache, extras = model.prefill(params, batch, max_seq=max_seq)
    h_all = extras["h_final"]                              # (B, S, D)
    if sw is not None:
        embeds = model.embed(params, batch["tokens"])
        dcache = draft_lib.draft_prefill(model.cfg, sw.draft, embeds, h_all,
                                         max_seq)
    else:
        dcache = {}
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    state = DecodeState(
        cache=cache,
        draft_cache=dcache,
        sched=sched_lib.init_state(h_all.shape[0], spec),
        last_token=first,
        h_last=h_all[:, -1, :],
        prng=prng if prng is not None else jax.random.PRNGKey(0),
    )
    return first, state


def empty_decode_state(model: Model, sw: Optional[SpecEEWeights], batch: int,
                       max_seq: int, prng=None, cache=None) -> DecodeState:
    """All-zeros batched state with ``batch`` empty slots — the serving
    engine's starting point: rows are later populated by inserting batch-1
    ``init_decode_state`` results (continuous batching).

    ``cache``: a pre-built cache pytree from a ``KVCacheManager``
    (``repro.api.cache``) — e.g. the paged pool + page table layout; None
    keeps the historical dense allocation."""
    dtype = common.dtype_of(model.cfg.dtype)
    return DecodeState(
        cache=cache if cache is not None else model.empty_cache(batch,
                                                                max_seq),
        draft_cache=(draft_lib.draft_cache(model.cfg, batch, max_seq, dtype)
                     if sw is not None else {}),
        sched=sched_lib.init_state(batch, model.run.specee),
        last_token=jnp.zeros((batch,), jnp.int32),
        h_last=jnp.zeros((batch, model.cfg.d_model), dtype),
        prng=prng if prng is not None else jax.random.PRNGKey(0),
    )


# ---------------------------------------------------------------------------
# autoregressive SpecEE step
# ---------------------------------------------------------------------------
def ar_decode_step(model: Model, params: Params, sw: SpecEEWeights,
                   state: DecodeState,
                   threshold: Optional[float] = None,
                   spec_ids_override: Optional[jnp.ndarray] = None,
                   qw=None, shard=None
                   ) -> Tuple[jnp.ndarray, DecodeState, StepInfo]:
    """Decode one token for every row with speculative early exiting.

    spec_ids_override: (B, k) — oracle speculative set for tests/upper-bound
    benchmarks (bypasses the draft proposal, draft cache still maintained).
    qw: optional quantized-weight bundle (``repro.quant.quantize_params``).
    shard: optional ShardCtx — routes every full-LM-head reduction (the
    draft proposal's top-k, the exit verify, the final emit) through the
    per-shard partial paths (DESIGN.md §9); the predictor-MLP/spec-head
    gates run replicated per shard.
    """
    spec = model.run.specee
    thresh = spec.exit_threshold if threshold is None else threshold
    E = model.num_exit_points
    params, lm_w, predictors = _apply_qw(params, sw, qw)
    pos = state.cache["len"]
    B = state.last_token.shape[0]
    k = spec.num_speculative
    gate_impl, _ = _gate_impls(model)
    sh_kernel = getattr(model.flags, "spec_head_kernel", False)
    pages = state.cache.get("page_table")       # paged KV: table indirection

    # ---- 1. speculate: draft proposes k candidate tokens ----
    emb = model.embed(params, state.last_token[:, None])[:, 0, :]
    h_draft, draft_cache = draft_lib.draft_step(
        model.cfg, sw.draft, emb, state.h_last, state.draft_cache, pos)
    spec_ids, _ = draft_lib.propose_topk(model, params, h_draft, k,
                                         lm_w=lm_w, shard=shard)
    if spec_ids_override is not None:
        spec_ids = spec_ids_override

    # ---- 2. T2 scheduling: which exit points run a predictor ----
    active = sched_lib.active_mask(state.sched, sw.offline_mask, spec, E)

    # ---- 3. layer loop with early exit ----
    h = emb
    exited = jnp.zeros((B,), bool)
    exit_token = jnp.zeros((B,), jnp.int32)
    exit_pt = jnp.full((B,), E, jnp.int32)
    prev_probs = jnp.full((B, k), 1.0 / k, jnp.float32)
    units_run = jnp.int32(0)
    new_segs = []
    ep_base = 0
    for seg, (unit, reps) in enumerate(model.segments):
        seg_cache = state.cache["segments"][seg]

        def cond(c):
            u = c[0]
            return (u < reps) & ~jnp.all(c[3])

        def body(c):
            u, h, seg_cache, exited, exit_token, exit_pt, prev_probs, nrun = c
            live = ~exited
            h_new, seg_cache = model.run_unit(params, seg, u, h, seg_cache,
                                              pos, live_mask=live,
                                              pages=pages)
            h = jnp.where(exited[:, None], h, h_new)
            ep = ep_base + u                                   # global exit pt

            act = jnp.take(active, ep, axis=1) & live          # (B,)

            def with_predictor(args):
                h, prev_probs, exited, exit_token, exit_pt = args
                hn = model.final_norm(params, h)
                # single exit-gate entry point: spec-head features +
                # predictor fused ("kernel"/"xla") or the four-op reference
                p_exit, probs, _ = gate_lib.exit_gate(
                    hn, lm_w, spec_ids, prev_probs, predictors, ep,
                    impl=gate_impl, spec_head_kernel=sh_kernel)
                would = act & (p_exit > thresh)

                def verify(args2):
                    exited, exit_token, exit_pt = args2
                    gtok, _ = gate_lib.verify_argmax(hn, lm_w,
                                                     impl=gate_impl,
                                                     shard=shard)
                    confirmed = jnp.any(gtok[:, None] == spec_ids, axis=1)
                    newly = would & confirmed
                    exit_token = jnp.where(newly, gtok, exit_token)
                    exit_pt = jnp.where(newly, ep, exit_pt)
                    return exited | newly, exit_token, exit_pt

                exited, exit_token, exit_pt = jax.lax.cond(
                    jnp.any(would), verify, lambda a: a,
                    (exited, exit_token, exit_pt))
                prev_probs = jnp.where(act[:, None], probs, prev_probs)
                return prev_probs, exited, exit_token, exit_pt

            def without_predictor(args):
                h, prev_probs, exited, exit_token, exit_pt = args
                return prev_probs, exited, exit_token, exit_pt

            prev_probs, exited, exit_token, exit_pt = jax.lax.cond(
                jnp.any(act), with_predictor, without_predictor,
                (h, prev_probs, exited, exit_token, exit_pt))
            return (u + 1, h, seg_cache, exited, exit_token, exit_pt,
                    prev_probs, nrun + 1)

        carry = (jnp.int32(0), h, seg_cache, exited, exit_token, exit_pt,
                 prev_probs, units_run)
        u_end, h, seg_cache, exited, exit_token, exit_pt, prev_probs, \
            units_run = jax.lax.while_loop(cond, body, carry)

        # ---- 4. KV/state propagation for units the loop never reached ----
        def pcond(c):
            return c[0] < reps

        def pbody(c):
            u, seg_cache = c
            seg_cache = model.propagate_unit(params, seg, u, h, seg_cache,
                                             pos, pages=pages)
            return u + 1, seg_cache

        _, seg_cache = jax.lax.while_loop(pcond, pbody, (u_end, seg_cache))
        new_segs.append(seg_cache)
        ep_base += reps

    # ---- 5. emit: exited rows use the verified token, others the full head
    # (streamed through the verify kernel when fused — one LM-head pass) ----
    final_tok, _ = gate_lib.verify_argmax(model.final_norm(params, h), lm_w,
                                          impl=gate_impl, shard=shard)
    token = jnp.where(exited, exit_token, final_tok)
    spec_hit = jnp.any(token[:, None] == spec_ids, axis=1)

    # ---- 6. bookkeeping ----
    sched = sched_lib.update(state.sched,
                             jnp.minimum(exit_pt, E - 1))
    new_state = DecodeState(
        cache=dict(state.cache, segments=new_segs, len=pos + 1),
        draft_cache=draft_cache,
        sched=sched,
        last_token=token,
        h_last=h,
        prng=state.prng,
    )
    info = StepInfo(exit_point=exit_pt, exited=exited, units_run=units_run,
                    spec_hit=spec_hit)
    return token, new_state, info


# ---------------------------------------------------------------------------
# T3: tree speculative decoding with hyper-token merged early exit
# ---------------------------------------------------------------------------
class TreeStepInfo(NamedTuple):
    accepted_len: jnp.ndarray   # (B,) matched draft tokens (excl. bonus)
    exit_point: jnp.ndarray     # (B,) unit index at exit
    exited: jnp.ndarray         # (B,)
    units_run: jnp.ndarray      # ()


def build_tree(model: Model, params: Params, sw: SpecEEWeights,
               state: DecodeState, tree) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Draft-expand the static tree. Returns (node_tokens (B, N) int32,
    node_parent_hidden (B, N, D) draft hiddens, new draft cache)."""
    cfg = model.cfg
    B = state.last_token.shape[0]
    pos0 = state.cache["len"]
    b = tree.branch
    # root draft step (writes the trunk cache at pos0)
    emb = model.embed(params, state.last_token[:, None])[:, 0, :]
    h_root, draft_cache = draft_lib.draft_step(
        cfg, sw.draft, emb, state.h_last, state.draft_cache, pos0)

    node_tokens = jnp.zeros((B, tree.num_nodes), jnp.int32)
    node_tokens = node_tokens.at[:, 0].set(state.last_token)
    h_nodes = jnp.zeros((B, tree.num_nodes) + h_root.shape[-1:], h_root.dtype)
    h_nodes = h_nodes.at[:, 0].set(h_root)

    level_off = tree.level_offsets
    for lvl in range(1, tree.depth + 1):
        p_off, p_size = level_off[lvl - 1], tree.level_sizes[lvl - 1]
        off, size = level_off[lvl], tree.level_sizes[lvl]
        # children tokens = top-b of each parent's draft logits
        hp = h_nodes[:, p_off:p_off + p_size].reshape(B * p_size, -1)
        logits = model.logits(params, hp)
        _, topb = jax.lax.top_k(logits, b)
        toks = topb.astype(jnp.int32).reshape(B, p_size * b)
        node_tokens = jax.lax.dynamic_update_slice_in_dim(
            node_tokens, toks, off, axis=1)
        if lvl < tree.depth:  # need hiddens to expand further
            emb_c = model.embed(params, toks.reshape(B * size, 1))[:, 0, :]
            hp_rep = jnp.repeat(hp.reshape(B, p_size, -1), b, axis=1
                                ).reshape(B * size, -1)
            h_c = draft_lib.draft_step_readonly(
                cfg, sw.draft, emb_c, hp_rep, draft_cache, pos0 + lvl,
                pos0 + 1)
            h_nodes = jax.lax.dynamic_update_slice_in_dim(
                h_nodes, h_c.reshape(B, size, -1), off, axis=1)
    return node_tokens, h_nodes, draft_cache


def tree_decode_step(model: Model, params: Params, sw: SpecEEWeights,
                     state: DecodeState, tree,
                     threshold: Optional[float] = None,
                     node_tokens_override: Optional[jnp.ndarray] = None,
                     qw=None, shard=None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, DecodeState,
                                TreeStepInfo]:
    """One tree-speculative step with hyper-token merged early exit.

    Returns (tokens (B, depth+1) emitted left-aligned, num_emitted (B,),
    new state, info). Cache must have ``tree.num_nodes`` scratch slots beyond
    ``max_seq`` (see ``init_tree_decode_state``).
    """
    assert model.supports_tree(), \
        "T3 tree mode requires a pure-attention stack (DESIGN.md §4)"
    spec = model.run.specee
    thresh = spec.exit_threshold if threshold is None else threshold
    E = model.num_exit_points
    params, lm_w, predictors = _apply_qw(params, sw, qw)
    B = state.last_token.shape[0]
    N = tree.num_nodes
    k = spec.num_speculative
    pos0 = state.cache["len"]
    gate_impl, fused = _gate_impls(model)
    sh_kernel = getattr(model.flags, "spec_head_kernel", False)
    # the tree gate's predictor stage goes through the Pallas wrapper only
    # when the fused backend actually resolves to the kernel path
    pred_kernel = fused and gate_lib.resolve_impl(gate_impl) == "kernel"
    # static scratch offset = logical capacity minus N; with a paged cache
    # the capacity is pages_per_row * page_size (table width × pool page dim)
    pages = state.cache.get("page_table")
    any_k = jax.tree_util.tree_leaves(state.cache["segments"][0])[0]
    if pages is None:
        capacity = any_k.shape[2]
    else:
        capacity = pages.shape[1] * any_k.shape[2]
    scratch_off = capacity - N

    node_tokens, h_nodes_draft, draft_cache = build_tree(
        model, params, sw, state, tree)
    if node_tokens_override is not None:  # oracle mode for tests/benchmarks
        node_tokens = node_tokens_override.at[:, 0].set(state.last_token)

    # children token matrix per node, padded to k for the predictor features
    children = jnp.asarray(tree.children)                   # (N, b)
    safe_children = jnp.maximum(children, 0)
    child_toks = node_tokens[:, safe_children]              # (B, N, b)
    if tree.branch < k:
        pad = jnp.repeat(child_toks[:, :, :1], k - tree.branch, axis=2)
        child_toks = jnp.concatenate([child_toks, pad], axis=2)
    else:
        child_toks = child_toks[:, :, :k]

    # ---- layer loop with hyper-token early exit ----
    mask = tree.attention_mask(pos0, scratch_off)           # (B|1,1,N,S+N)
    positions = jnp.broadcast_to(tree.positions(pos0), (B, N))
    h = model.embed(params, node_tokens)                    # (B, N, D)
    exited = jnp.zeros((B,), bool)
    exit_pt = jnp.full((B,), E, jnp.int32)
    prev_probs = jnp.full((B, N, k), 1.0 / k, jnp.float32)
    units_run = jnp.int32(0)
    active = sched_lib.active_mask(state.sched, sw.offline_mask, spec, E)
    path_nodes = jnp.asarray(tree.path_nodes)               # (P, depth+1)
    new_segs = []
    ep_base = 0
    for seg, (unit, reps) in enumerate(model.segments):
        seg_cache = state.cache["segments"][seg]

        def cond(c):
            return (c[0] < reps) & ~jnp.all(c[3])

        def body(c):
            u, h, seg_cache, exited, exit_pt, prev_probs, nrun = c
            live = ~exited
            h_new, seg_cache = model.run_unit_tree(
                params, seg, u, h, seg_cache, mask, positions, scratch_off,
                pages=pages)
            h = jnp.where(exited[:, None, None], h, h_new)
            ep = ep_base + u
            act = jnp.take(active, ep, axis=1) & live

            def with_predictor(args):
                h, prev_probs, exited, exit_pt = args
                hn = model.final_norm(params, h).reshape(B * N, -1)
                feats, probs = feat_lib.extract_features(
                    hn, lm_w, child_toks.reshape(B * N, k),
                    prev_probs.reshape(B * N, k), use_kernel=sh_kernel)
                feats = feats.reshape(B, N, -1)
                probs = probs.reshape(B, N, k)
                # hyper-token merge: one predictor eval per root→leaf path
                pf, _ = feat_lib.merge_path_features(
                    feats, probs, path_nodes,
                    jnp.full((path_nodes.shape[0],), path_nodes.shape[1]))
                p_exit = pred_lib.apply_predictor_banked(
                    predictors, ep, pf,
                    use_kernel=pred_kernel)                    # (B, P)
                fire = jnp.max(p_exit, axis=1) > thresh     # best path fires
                newly = act & fire
                exit_pt = jnp.where(newly, ep, exit_pt)
                prev_probs = jnp.where(act[:, None, None], probs, prev_probs)
                return prev_probs, exited | newly, exit_pt

            prev_probs, exited, exit_pt = jax.lax.cond(
                jnp.any(act), with_predictor,
                lambda a: (a[1], a[2], a[3]),
                (h, prev_probs, exited, exit_pt))
            return u + 1, h, seg_cache, exited, exit_pt, prev_probs, nrun + 1

        carry = (jnp.int32(0), h, seg_cache, exited, exit_pt, prev_probs,
                 units_run)
        u_end, h, seg_cache, exited, exit_pt, prev_probs, units_run = \
            jax.lax.while_loop(cond, body, carry)

        def pcond(c):
            return c[0] < reps

        def pbody(c):
            u, sc = c
            sc = model.propagate_unit_tree(params, seg, u, h, sc, positions,
                                           scratch_off, pages=pages)
            return u + 1, sc

        _, seg_cache = jax.lax.while_loop(pcond, pbody, (u_end, seg_cache))
        new_segs.append(seg_cache)
        ep_base += reps

    # ---- acceptance walk on global logits at the (per-row) exit layer ----
    # B·N node rows stream through the verify kernel when fused: one LM-head
    # pass, never a (B, N, V) logits tensor
    hn_nodes = model.final_norm(params, h).reshape(B * N, -1)
    gtok = gate_lib.verify_argmax(hn_nodes, lm_w, impl=gate_impl,
                                  shard=shard)[0].reshape(B, N)

    rows = jnp.arange(B)
    cur = jnp.zeros((B,), jnp.int32)                        # root
    acc_nodes = jnp.full((B, tree.depth + 1), -1, jnp.int32)
    acc_nodes = acc_nodes.at[:, 0].set(0)
    acc_len = jnp.ones((B,), jnp.int32)                     # root always in
    out_tokens = jnp.zeros((B, tree.depth + 1), jnp.int32)
    n_emit = jnp.zeros((B,), jnp.int32)
    alive = jnp.ones((B,), bool)
    for d in range(1, tree.depth + 1):
        target = gtok[rows, cur]                            # (B,)
        ch = safe_children[cur]                             # (B, b)
        ch_tok = node_tokens[rows[:, None], ch]             # (B, b)
        match = (ch_tok == target[:, None]) & (children[cur] >= 0)
        hit = jnp.any(match, axis=1) & alive
        nxt = ch[rows, jnp.argmax(match, axis=1)]
        out_tokens = out_tokens.at[:, d - 1].set(
            jnp.where(hit, target, out_tokens[:, d - 1]))
        n_emit = n_emit + hit.astype(jnp.int32)
        acc_nodes = acc_nodes.at[:, d].set(jnp.where(hit, nxt, -1))
        acc_len = acc_len + hit.astype(jnp.int32)
        cur = jnp.where(hit, nxt, cur)
        alive = hit
    # bonus token: TLM greedy at the last accepted node
    bonus = gtok[rows, cur]
    out_tokens = out_tokens.at[rows, n_emit].set(bonus)
    n_emit = n_emit + 1

    # ---- commit: copy accepted K/V into real cache positions ----
    cache = dict(state.cache, segments=new_segs, len=pos0)
    cache = model.accept_tree_kv(cache, acc_nodes, acc_len, pos0, scratch_off)
    cache["len"] = pos0 + acc_len                           # root + matched

    # ---- draft cache catch-up for accepted tokens beyond the root ----
    h_last = h[rows, cur]                                   # (B, D) exit hidden
    for d in range(1, tree.depth + 1):
        valid = d < acc_len
        tok_d = out_tokens[:, d - 1]                        # accepted token d
        emb_d = model.embed(params, tok_d[:, None])[:, 0, :]
        parent_h = h[rows, jnp.maximum(acc_nodes[:, d - 1], 0)]
        h_d, dc_new = draft_lib.draft_step(
            model.cfg, sw.draft, emb_d, parent_h, draft_cache, pos0 + d)
        draft_cache = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                valid[:, None, None, None], new, old), dc_new, draft_cache)

    sched = sched_lib.update(state.sched, jnp.minimum(exit_pt, E - 1))
    new_state = DecodeState(cache=cache, draft_cache=draft_cache, sched=sched,
                            last_token=bonus, h_last=h_last, prng=state.prng)
    info = TreeStepInfo(accepted_len=acc_len - 1, exit_point=exit_pt,
                        exited=exited, units_run=units_run)
    return out_tokens, n_emit, new_state, info


# ---------------------------------------------------------------------------
# device-resident multi-tick decode ("megatick")
# ---------------------------------------------------------------------------
class TickEmit(NamedTuple):
    """Raw per-tick emit of one strategy step, as the megatick loop sees it."""
    tokens: jnp.ndarray         # (B, W) int32 — left-aligned emitted tokens
    counts: jnp.ndarray         # (B,) int32 — valid tokens this tick
    exit_layer: jnp.ndarray     # (B,) int32
    accept_len: jnp.ndarray     # (B,) int32
    exited: jnp.ndarray         # (B,) bool
    units_run: jnp.ndarray      # () int32


def megatick_decode(tick_fn, state: DecodeState, limits: Dict[str, jnp.ndarray],
                    num_ticks: int, emit_width: int, num_exit_points: int
                    ) -> Tuple[Dict[str, jnp.ndarray], DecodeState,
                               Dict[str, jnp.ndarray]]:
    """Fuse ``num_ticks`` strategy steps into one ``lax.while_loop``.

    ``tick_fn(state) -> (TickEmit, new_state)`` is one batched strategy step
    (any decode mode). The per-row token budgets, EOS cut-off, and done mask —
    historically host-side Python in ``DecodeSession`` — live in the jitted
    carry, so the whole megatick runs device-resident: emits accumulate into a
    ``(B, K*W)`` buffer at per-row offsets, per-tick exit-layer/accept-length
    stats land in ``(B, K)`` columns, and the loop exits early once every row
    is done. Rows retired mid-flight (``limits["retired"]``) have their
    logical cache length re-pinned to zero after every tick, preserving the
    session's sticky-compaction invariant without a host sync.

    Accounting is tick-for-tick identical to ``DecodeSession._account_row``:
    budget clip first, EOS scan within the clipped window, ``done`` on EOS hit
    or budget exhaustion; rows already done keep stepping (their emits are
    dropped — exactly what K single host-accounted steps do) so device state
    stays bit-identical to the unfused loop.

    Returns ``(out, final_state, new_limits)`` where ``out`` holds tokens
    (B, K·W), counts (B,), per-tick stat planes (B, K), ``ticks`` actually
    run, and the final done mask; ``new_limits`` is the advanced carry for
    the next megatick (device-resident across calls — no host round-trip).
    """
    K, W = int(num_ticks), int(emit_width)
    B = state.last_token.shape[0]
    buf_len = K * W
    budget = limits["budget"]
    eos = limits["eos"]
    retired = limits["retired"]
    lanes = jnp.arange(W)

    def write_rows(buf, off, toks, kept):
        # per-row scatter at the row's running offset; lanes >= kept map out
        # of range and drop (the buffer is exactly K*W: a row that keeps j
        # tokens per tick never writes past its own accumulated count)
        idx = jnp.where(lanes[None, :] < kept[:, None],
                        off[:, None] + lanes[None, :], buf_len)
        return jax.vmap(lambda b, i, t: b.at[i].set(t, mode="drop"))(
            buf, idx, toks)

    def cond(c):
        return (c["t"] < K) & ~jnp.all(c["done"])

    def body(c):
        t, done, emitted = c["t"], c["done"], c["emitted"]
        em, st = tick_fn(c["state"])
        live = ~done
        # budget clip, then EOS scan within the clipped window (the exact
        # order of the host-side _account_row)
        kept = jnp.maximum(jnp.minimum(em.counts, budget - emitted), 0)
        is_eos = ((em.tokens == eos[:, None]) & (eos >= 0)[:, None]
                  & (lanes[None, :] < kept[:, None]))
        has_eos = jnp.any(is_eos, axis=1)
        kept = jnp.where(has_eos,
                         jnp.argmax(is_eos, axis=1).astype(jnp.int32) + 1,
                         kept)
        kept = jnp.where(live, kept, 0)
        emitted = emitted + kept
        done = done | (live & (has_eos | (emitted >= budget)))
        buf = write_rows(c["buf"], c["counts"], em.tokens, kept)
        # sticky compaction: the batched tick advances len uniformly; a
        # retired row's span must stay pinned to zero
        cache = st.cache
        st = st._replace(cache=dict(cache,
                                    len=jnp.where(retired, 0, cache["len"])))
        return dict(
            state=st, t=t + 1, done=done, emitted=emitted, buf=buf,
            counts=c["counts"] + kept,
            exit_layer=c["exit_layer"].at[:, t].set(em.exit_layer),
            accept_len=c["accept_len"].at[:, t].set(em.accept_len),
            exited=c["exited"].at[:, t].set(em.exited),
            tick_counts=c["tick_counts"].at[:, t].set(kept),
            tick_live=c["tick_live"].at[:, t].set(live),
            units=c["units"] + em.units_run,
        )

    init = dict(
        state=state, t=jnp.int32(0), done=limits["done"],
        emitted=limits["emitted"],
        buf=jnp.zeros((B, buf_len), jnp.int32),
        counts=jnp.zeros((B,), jnp.int32),
        exit_layer=jnp.full((B, K), num_exit_points, jnp.int32),
        accept_len=jnp.zeros((B, K), jnp.int32),
        exited=jnp.zeros((B, K), bool),
        tick_counts=jnp.zeros((B, K), jnp.int32),
        tick_live=jnp.zeros((B, K), bool),
        units=jnp.int32(0),
    )
    fin = jax.lax.while_loop(cond, body, init)
    out = {"tokens": fin["buf"], "counts": fin["counts"],
           "exit_layer": fin["exit_layer"], "accept_len": fin["accept_len"],
           "exited": fin["exited"], "tick_counts": fin["tick_counts"],
           "tick_live": fin["tick_live"], "ticks": fin["t"],
           "units_run": fin["units"], "done": fin["done"]}
    new_limits = {"budget": budget, "emitted": fin["emitted"], "eos": eos,
                  "done": fin["done"], "retired": retired}
    return out, fin["state"], new_limits


def init_tree_decode_state(model: Model, params: Params, sw: SpecEEWeights,
                           batch: Dict[str, jnp.ndarray], max_seq: int,
                           tree) -> Tuple[jnp.ndarray, DecodeState]:
    """Like ``init_decode_state`` but reserves N scratch slots in the cache
    (cache lengths are per-row throughout — rows accept ragged counts)."""
    return init_decode_state(model, params, sw, batch,
                             max_seq + tree.num_nodes)


# ---------------------------------------------------------------------------
# dense baseline step sharing the same state plumbing (for A/B benchmarks
# and the serving engine's non-SpecEE mode)
# ---------------------------------------------------------------------------
def dense_decode_step(model: Model, params: Params,
                      sw: Optional[SpecEEWeights], state: DecodeState,
                      temperature: float = 0.0, top_k: Optional[int] = None,
                      qw=None, shard=None
                      ) -> Tuple[jnp.ndarray, DecodeState, StepInfo]:
    """One dense (full-depth) decode step.

    Greedy (``temperature<=0``) emits through ``gate_lib.verify_argmax`` —
    the LM head streams with the exit-gate impl the model's flags select, so
    the fused path stops materializing (B, V) logits here too ("ref" keeps
    the historical materialized argmax bit-for-bit). ``temperature>0``
    samples from the full logits (sampling needs the distribution) with a
    per-row key derived from (session key, row position, previous token) —
    ``sampler.row_keys`` — so a row's samples are a pure function of its own
    decode history: batch- and slot-independent, megatick-invariant, and
    exactly reproducible when an evicted row replays its prefix through the
    fault-recovery path (DESIGN.md §7). ``state.prng`` stays constant.

    With a quantized bundle (``qw``) the greedy path verifies against the
    quantized head; the sampling path keeps the fp LM head (the distribution
    is the product, not just its argmax) while still using dequantized
    projections.
    """
    params, lm_w, _ = _apply_qw(params, sw, qw)
    pos_before = state.cache["len"]
    h, cache = model.decode_step_hidden(params, state.last_token, state.cache)
    if temperature > 0.0:
        from repro.serving.sampler import row_keys, sample_rows
        keys = row_keys(state.prng, pos_before, state.last_token)
        logits = model.logits(params, h)
        token = sample_rows(logits, keys, temperature=temperature,
                            top_k=top_k)
        prng = state.prng
    else:
        prng = state.prng
        gate_impl, _ = _gate_impls(model)
        token, _ = gate_lib.verify_argmax(model.final_norm(params, h),
                                          lm_w, impl=gate_impl, shard=shard)
    B = token.shape[0]
    E = model.num_exit_points
    new_state = DecodeState(cache=cache, draft_cache=state.draft_cache,
                            sched=state.sched, last_token=token,
                            h_last=h, prng=prng)
    info = StepInfo(exit_point=jnp.full((B,), E, jnp.int32),
                    exited=jnp.zeros((B,), bool),
                    units_run=jnp.int32(E),
                    spec_hit=jnp.zeros((B,), bool))
    return token, new_state, info
