"""Draft (DLM) training — EAGLE-style alignment with the frozen target
(paper §7.4.3: "the speculative model for Llama2-7B only needs 24 hours on an
RTX 3090"; our smoke-scale analogue takes seconds).

Objective (teacher-forced over the frozen TLM):
  * token loss: CE of the draft hidden (through the TLM's LM head) against
    the TLM's own greedy next token — aligns the draft's top-k with the TLM;
  * feature loss: L2 between draft hidden and the TLM hidden of the same
    position (EAGLE's feature-uncertainty recipe).
Only the draft parameters train; the target model is frozen throughout
(SpecEE never touches original weights).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import draft as draft_lib
from repro.models.common import Params, lm_head_weight
from repro.models.model import Model


def _teacher(model: Model, params: Params, tokens: jnp.ndarray):
    """Frozen-TLM quantities: embeds, hiddens, greedy next tokens."""
    B, S = tokens.shape
    h = model.embed(params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    hf, _, _ = model.forward_hidden(params, h, positions)
    logits = model.logits(params, hf)              # (B, S, V)
    greedy = jnp.argmax(logits, axis=-1)           # token at t+1
    return h, hf, greedy


def draft_loss(model: Model, params: Params, dp: Params,
               tokens: jnp.ndarray, feat_weight: float = 0.1):
    embeds, hf, greedy = _teacher(model, params, tokens)
    h_draft = draft_lib.draft_forward_seq(model.cfg, dp, embeds,
                                          draft_lib.shift_hidden(hf))
    dlogits = model.logits(params, h_draft)        # (B, S, V) fp32
    lse = jax.nn.log_softmax(dlogits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(lse, greedy[..., None], -1))
    feat = jnp.mean(jnp.square(h_draft.astype(jnp.float32) -
                               hf.astype(jnp.float32)))
    return ce + feat_weight * feat, (ce, feat)


def train_draft(model: Model, params: Params, token_batches: List[jnp.ndarray],
                key, steps: int = 200, lr: float = 1e-3
                ) -> Tuple[Params, Dict[str, float]]:
    dp = draft_lib.init_draft(model.cfg, key)
    flat, tree = jax.tree_util.tree_flatten(dp)
    m = [jnp.zeros_like(x) for x in flat]
    v = [jnp.zeros_like(x) for x in flat]

    @partial(jax.jit, static_argnums=())
    def step(dp, m, v, i, tokens):
        (loss, _), g = jax.value_and_grad(
            lambda d: draft_loss(model, params, d, tokens), has_aux=True)(dp)
        m_t = jax.tree_util.tree_unflatten(tree, m)
        v_t = jax.tree_util.tree_unflatten(tree, v)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m_t = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b,
                                     m_t, g)
        v_t = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b,
                                     v_t, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** (i + 1)), m_t)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** (i + 1)), v_t)
        dp = jax.tree_util.tree_map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), dp, mh, vh)
        return dp, jax.tree_util.tree_leaves(m_t), \
            jax.tree_util.tree_leaves(v_t), loss

    loss = None
    for i in range(steps):
        tokens = token_batches[i % len(token_batches)]
        dp, m, v, loss = step(dp, m, v, i, tokens)
    metrics = {"final_loss": float(loss)}
    metrics.update(topk_hit_rate(model, params, dp, token_batches[0],
                                 model.run.specee.num_speculative))
    return dp, metrics


def topk_hit_rate(model: Model, params: Params, dp: Params,
                  tokens: jnp.ndarray, k: int) -> Dict[str, float]:
    """Fraction of positions where the TLM's greedy token is inside the
    draft's top-k proposal — the quantity that gates SpecEE verification."""
    embeds, hf, greedy = _teacher(model, params, tokens)
    h_draft = draft_lib.draft_forward_seq(model.cfg, dp, embeds,
                                          draft_lib.shift_hidden(hf))
    dlogits = model.logits(params, h_draft)
    _, topk = jax.lax.top_k(dlogits, k)
    hit = jnp.any(topk == greedy[..., None], axis=-1)
    return {"topk_hit_rate": float(jnp.mean(hit))}
