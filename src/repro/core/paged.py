"""Page-table indirection primitives for the paged KV cache.

A paged attention cache entry stores K/V (and int8 scales) in a *page pool*
leaf of shape ``(n_pages, page_size, ...)`` shared by every row of the batch;
a per-session ``page_table`` of shape ``(B, pages_per_row)`` int32 maps each
row's logical pages onto physical page ids. Logical position ``p`` of row
``b`` therefore lives at flat pool slot

    table[b, p // page_size] * page_size + p % page_size

These helpers are the ONLY place that math lives: the model's decode paths
(`repro.models.model`), the tree-accept copy, and the cache manager
(`repro.api.cache`) all read and write pool leaves through them, so the
logical view they expose is bit-identical to the dense ``(B, S, ...)`` layout
(the dense reference keeps masked softmax semantics; padded logical slots
beyond a row's ``len`` are never read).

Everything here is pure jnp and jit-compatible; page allocation itself is
host-side (see ``repro.api.cache.PagedKVCache``) — the jitted step functions
only ever *index through* an already-populated table.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def page_size_of(pool: jnp.ndarray) -> int:
    """Static page size of an (unstacked) pool leaf ``(n_pages, ps, ...)``."""
    return pool.shape[1]


def logical_capacity(table: jnp.ndarray, page_size: int) -> int:
    """Logical sequence capacity per row: pages_per_row * page_size."""
    return table.shape[1] * page_size


def flat_slots(table: jnp.ndarray, page_size: int,
               pos: jnp.ndarray) -> jnp.ndarray:
    """Flat pool slot ids for logical positions.

    table: (B, P) int32; pos: (B,) or (B, L) int32 logical positions.
    Returns int32 of the same shape as ``pos``.
    """
    pos = jnp.asarray(pos, jnp.int32)
    squeeze = pos.ndim == 1
    pm = pos[:, None] if squeeze else pos                    # (B, L)
    page = jnp.take_along_axis(table, pm // page_size, axis=1)
    slots = page * page_size + pm % page_size
    return slots[:, 0] if squeeze else slots


def view_slots(table: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """(B, P*page_size) flat slot id of every logical position of every row."""
    B, P = table.shape
    slots = table[:, :, None] * page_size + jnp.arange(page_size)[None, None, :]
    return slots.reshape(B, P * page_size)


def _flat(pool: jnp.ndarray) -> jnp.ndarray:
    """(n_pages, ps, ...) -> (n_pages*ps, ...)."""
    return pool.reshape((pool.shape[0] * pool.shape[1],) + pool.shape[2:])


def gather_view(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Materialize the logical per-row view of a pool leaf.

    pool: (n_pages, ps, ...); table: (B, P). Returns (B, P*ps, ...) — the
    exact layout the dense cache reference stores directly, so downstream
    attention math is unchanged (and bit-identical: masked positions never
    contribute to the softmax regardless of their gathered contents).
    """
    ps = page_size_of(pool)
    return _flat(pool)[view_slots(table, ps)]


def scatter_token(pool: jnp.ndarray, table: jnp.ndarray, pos: jnp.ndarray,
                  vals: jnp.ndarray) -> jnp.ndarray:
    """Write one value per row at logical position ``pos``.

    pool: (n_pages, ps, ...); pos: (B,); vals: (B, ...). Distinct live rows
    hold distinct pages so the scatter is conflict-free (retired rows all
    alias the trash page, whose contents are never read).
    """
    ps = page_size_of(pool)
    slots = flat_slots(table, ps, pos)                       # (B,)
    return _flat(pool).at[slots].set(vals.astype(pool.dtype)).reshape(pool.shape)


def scatter_slab(pool: jnp.ndarray, table: jnp.ndarray, pos: jnp.ndarray,
                 vals: jnp.ndarray) -> jnp.ndarray:
    """Write an (B, L, ...) slab at logical positions ``pos`` (B, L)."""
    ps = page_size_of(pool)
    slots = flat_slots(table, ps, pos)                       # (B, L)
    return _flat(pool).at[slots].set(vals.astype(pool.dtype)).reshape(pool.shape)


def gather_positions(pool: jnp.ndarray, table: jnp.ndarray,
                     pos: jnp.ndarray) -> jnp.ndarray:
    """Read values at per-row logical positions. pos: (B,) -> (B, ...)."""
    ps = page_size_of(pool)
    return _flat(pool)[flat_slots(table, ps, pos)]


def paged_shape(dense_shape: Tuple[int, ...], num_pages: int,
                page_size: int) -> Tuple[int, ...]:
    """Map a dense cache leaf shape (B, S, ...) to its pool shape
    (num_pages, page_size, ...)."""
    return (num_pages, page_size) + tuple(dense_shape[2:])


def pool_partition_dims(shape: Tuple[int, ...],
                        model_extent: int) -> Tuple[Optional[str], ...]:
    """Mesh-aware pool layout: which dim of a pool leaf shards over the
    tensor-parallel ('model') mesh axis.

    Page ids index the leading pool dims — (reps?, n_pages, page_size) —
    so those MUST stay replicated (every shard resolves the same page
    table); the shardable dims are the trailing per-token feature dims:
    the KV-head dim when it divides the TP degree, else head_dim, else
    nothing. Returns a dims tuple for ``PartitionSpec(*dims)``.
    """
    dims: list = [None] * len(shape)
    if model_extent > 1:
        for cand in (len(shape) - 2, len(shape) - 1):
            # cand >= 3 keeps (reps, n_pages, page_size) unsharded even for
            # low-rank leaves (e.g. 4D per-page scale planes)
            if cand >= 3 and shape[cand] % model_extent == 0:
                dims[cand] = "model"
                break
    return tuple(dims)
