"""T3 — static token tree for speculative decoding + hyper-token paths.

A full ``branch``-ary tree of ``depth`` draft levels under a root node:
node 0 is the *root* — the last accepted token (the TLM input for the current
position); level-ℓ nodes (ℓ ≥ 1) are draft candidates for position pos0+ℓ.
BFS (level-major) node numbering.

The hyper-token mapping (paper §6.2) merges every root→leaf path into one
predictor search space; ``paths()`` enumerates them with node-index matrices
used by ``features.merge_path_features``.

All structure is static numpy (shapes fixed at trace time); only token values
are traced.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp


@dataclass(frozen=True)
class TreeSpec:
    depth: int = 2     # draft levels under the root
    branch: int = 3    # children per node

    @cached_property
    def level_sizes(self) -> List[int]:
        return [1] + [self.branch ** l for l in range(1, self.depth + 1)]

    @cached_property
    def num_nodes(self) -> int:
        return sum(self.level_sizes)

    @cached_property
    def level_offsets(self) -> List[int]:
        offs, acc = [], 0
        for s in self.level_sizes:
            offs.append(acc)
            acc += s
        return offs

    @cached_property
    def levels(self) -> np.ndarray:
        """(N,) level of each node (root = 0)."""
        out = np.zeros(self.num_nodes, np.int32)
        for l, (off, size) in enumerate(zip(self.level_offsets, self.level_sizes)):
            out[off:off + size] = l
        return out

    @cached_property
    def parents(self) -> np.ndarray:
        """(N,) parent node index; root's parent = -1."""
        par = np.full(self.num_nodes, -1, np.int32)
        for l in range(1, self.depth + 1):
            off, size = self.level_offsets[l], self.level_sizes[l]
            poff = self.level_offsets[l - 1]
            for i in range(size):
                par[off + i] = poff + i // self.branch
        return par

    @cached_property
    def ancestor_mask(self) -> np.ndarray:
        """(N, N) bool: M[i, j] = node i attends node j (j ancestor-or-self)."""
        N = self.num_nodes
        m = np.eye(N, dtype=bool)
        for i in range(N):
            p = self.parents[i]
            while p >= 0:
                m[i, p] = True
                p = self.parents[p]
        return m

    @cached_property
    def path_nodes(self) -> np.ndarray:
        """(P, depth+1) node indices of each root→leaf path."""
        leaves_off = self.level_offsets[self.depth]
        leaves = np.arange(leaves_off, leaves_off + self.level_sizes[self.depth])
        P = len(leaves)
        out = np.zeros((P, self.depth + 1), np.int32)
        for pi, leaf in enumerate(leaves):
            chain = []
            n = leaf
            while n >= 0:
                chain.append(n)
                n = self.parents[n]
            out[pi] = np.array(chain[::-1], np.int32)
        return out

    @cached_property
    def children(self) -> np.ndarray:
        """(N, branch) child node indices (-1 where none — leaves)."""
        ch = np.full((self.num_nodes, self.branch), -1, np.int32)
        for i in range(self.num_nodes):
            p = self.parents[i]
            if p >= 0:
                slot = np.argmax(ch[p] < 0)
                ch[p, slot] = i
        return ch

    def attention_mask(self, cache_len, max_seq: int) -> jnp.ndarray:
        """(B|1, 1, N, max_seq + N) bool mask for the tree-verification step.

        Tree queries attend all valid cache positions (< cache_len, which may
        be per-row) plus their tree ancestors (incl. self), which sit at slots
        [max_seq, max_seq+N).
        """
        N = self.num_nodes
        kpos = jnp.arange(max_seq)[None, :]
        clen = jnp.reshape(cache_len, (-1, 1))              # (B|1, 1)
        ctx = jnp.broadcast_to((kpos < clen)[:, None, :],
                               (clen.shape[0], N, max_seq))
        tree = jnp.broadcast_to(jnp.asarray(self.ancestor_mask)[None],
                                (clen.shape[0], N, N))
        return jnp.concatenate([ctx, tree], axis=2)[:, None]

    def positions(self, pos0) -> jnp.ndarray:
        """(B|1, N) absolute position of each node: pos0 + level."""
        p0 = jnp.reshape(jnp.asarray(pos0, jnp.int32), (-1, 1))
        return p0 + jnp.asarray(self.levels)[None, :]
