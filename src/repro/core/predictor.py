"""T1 — the lightweight early-exit predictor (paper §4).

A 2-layer MLP (hidden 512, ReLU, sigmoid head, threshold 0.5) over the
12-dimensional speculation feature vector. Paper DSE (Fig. 8) fixes
(layers=2, hidden=512); both are configurable for the DSE benchmark.

One predictor per exit point, parameters stacked over exit points so the
decode loop can ``dynamic_index_in_dim`` into them. Total size for Llama2-7B
(32 predictors, k=4): (12·512 + 512 + 512·1 + 1) · 32 · 4B ≈ 416 KB — the
paper's §7.4.2 number (theirs omits biases: (12·512 + 512·1)·32·4 = 852 KB/2…
we assert the same order in tests).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.config import SpecEEConfig
from repro.models.common import KeyGen, Params, normal_init, zeros_init


def init_predictor(spec: SpecEEConfig, key) -> Params:
    """Single predictor MLP: feature_dim -> hidden^(layers-1) -> 1."""
    kg = KeyGen(key)
    dims = ([spec.feature_dim()] +
            [spec.predictor_hidden] * (spec.predictor_layers - 1) + [1])
    layers = []
    for i in range(len(dims) - 1):
        layers.append({
            "w": normal_init(kg(), (dims[i], dims[i + 1]),
                             1.0 / math.sqrt(dims[i])),
            "b": zeros_init((dims[i + 1],)),
        })
    return {"layers": layers}


def init_predictors(spec: SpecEEConfig, num_exit_points: int, key) -> Params:
    """Stacked predictors: every leaf gains a leading (num_exit_points,) dim."""
    keys = jax.random.split(key, num_exit_points)
    return jax.vmap(lambda k: init_predictor(spec, k))(keys)


def apply_predictor(p: Params, features: jnp.ndarray) -> jnp.ndarray:
    """features: (..., feature_dim) -> exit probability (...,) in [0, 1].

    Quantized banks (``repro.quant.QTensor`` weight leaves) are dequantized
    in place — this is the reference path the fused quantized MLP kernel is
    tested against.
    """
    x = features.astype(jnp.float32)
    layers = p["layers"]
    for i, layer in enumerate(layers):
        w = layer["w"]
        if hasattr(w, "dequantize"):
            w = w.dequantize()
        x = x @ w + layer["b"]
        if i + 1 < len(layers):
            x = jax.nn.relu(x)
    return jax.nn.sigmoid(x[..., 0])


def predictor_at(stacked: Params, idx: jnp.ndarray) -> Params:
    """Dynamic-index one predictor out of the stacked bank."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, idx, 0, False), stacked)


def apply_predictor_banked(stacked: Params, idx: jnp.ndarray,
                           features: jnp.ndarray,
                           use_kernel: bool = False) -> jnp.ndarray:
    """Single entry point for a stacked-bank predictor evaluation.

    ``use_kernel=True`` routes the bank ``dynamic_index`` + the fused 2-layer
    Pallas MLP through one jit (``repro.kernels.predictor_mlp.ops``); falls
    back to the reference path for non-2-layer banks (DSE sweeps).
    features: (..., feature_dim) -> exit probability (...,).
    """
    if use_kernel and len(stacked["layers"]) == 2:
        from repro.kernels.predictor_mlp.ops import predictor_mlp_at
        lead = features.shape[:-1]
        flat = features.reshape(-1, features.shape[-1])
        return predictor_mlp_at(flat, stacked, idx).reshape(lead)
    return apply_predictor(predictor_at(stacked, idx), features)


def predictor_param_bytes(spec: SpecEEConfig, num_exit_points: int) -> int:
    dims = ([spec.feature_dim()] +
            [spec.predictor_hidden] * (spec.predictor_layers - 1) + [1])
    per = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
    return per * num_exit_points * 4
