"""SpecEE core: the paper's contribution.

T1 (algorithm): ``features`` + ``predictor`` — speculation-based lightweight
    predictor over the k-token reduced search space.
T2 (system):    ``scheduler`` — two-level (offline + online) heuristic
    predictor scheduling.
T3 (mapping):   ``tree`` + hyper-token merged mapping inside ``engine``.

``engine`` assembles them into autoregressive and speculative decode loops;
``draft`` is the EAGLE-style speculative model; ``predictor_training`` is the
offline training pipeline (paper §7.4.4).
"""
