"""Decode-state + engine weight shardings for tensor-parallel serving.

The serving path (DESIGN.md §9) runs the megatick under GSPMD: ``Engine``
device_puts its weights with the Megatron-role specs from ``policies`` and
pins every ``DecodeState`` it hands to a session with ``decode_state_specs``
— the KV cache head-sharded over 'model' (paged pools via
``KVCacheManager.partition_specs``), everything else replicated so the
host-side admission/retire row edits stay layout-oblivious. The exit-gate
verify region is the one explicitly shard_mapped piece (``exit_gate.ops``);
its vocab-split partial-reduce contract is what keeps sharded decode
token-identical to single-device.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.sharding import policies as pol


def _replicated(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda x: P(*([None] * np.ndim(x))), tree)


def decode_state_specs(model, mesh: Mesh, policy: str, state,
                       cache_mgr=None) -> Any:
    """PartitionSpec pytree for a ``DecodeState``.

    KV cache: the manager's own layout when given (paged pools shard their
    head dim, page table / lengths replicated), else the generic
    ``cache_specs`` with sequence sharding OFF — decode appends one position
    per tick and a seq-sharded cache would ship every write cross-shard.
    Draft cache, scheduler state, last_token/h_last, PRNG: replicated — the
    draft layer and predictors run per-shard identically (paper §3.2: the
    speculation side is ~3% of the model; replicating it costs little and
    keeps its argmax bit-identical without any collective).
    """
    from repro.core import engine as eng
    if cache_mgr is not None:
        cache_spec = cache_mgr.partition_specs(state.cache, mesh, policy)
    else:
        cache_spec = pol.cache_specs(model, mesh, policy, state.cache,
                                     kv_seq_shard=False)
    return eng.DecodeState(
        cache=cache_spec,
        draft_cache=_replicated(state.draft_cache),
        sched=_replicated(state.sched),
        last_token=_replicated(state.last_token),
        h_last=_replicated(state.h_last),
        prng=_replicated(state.prng),
    )


def engine_shardings(model, mesh: Mesh, policy: str, params, sw, qw
                     ) -> Tuple[Any, Optional[Any], Optional[Any]]:
    """NamedSharding trees for (params, sw, qw).

    Params take the Megatron roles (column/row/vocab-parallel); SpecEE
    weights shard the draft layer like a TP block with predictors
    replicated; quantized tiles ride replicated — the int pools are already
    ~4-8x smaller than fp and the dequant-fused kernels index them
    locally (sharding them would need spec-aware tile offsets; the sharded
    verify path skips QTensor heads for the same reason, ops.py).
    """
    p_named = pol.named(mesh, pol.param_specs(model, mesh, policy, params))
    s_named = (pol.named(mesh, pol.specee_specs(model, mesh, policy, sw))
               if sw is not None else None)
    q_named = (pol.named(mesh, _replicated(qw)) if qw is not None else None)
    return p_named, s_named, q_named
