from repro.sharding.policies import (batch_specs, cache_specs, named,
                                     param_specs, specee_specs, state_specs)
