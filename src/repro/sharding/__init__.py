from repro.sharding.compat import (axis_types_kwargs, make_mesh, shard_map,
                                   shard_map_unchecked)
from repro.sharding.ctx import ShardCtx
from repro.sharding.policies import (batch_specs, cache_specs, named,
                                     param_specs, specee_specs, state_specs)
from repro.sharding.serving import decode_state_specs, engine_shardings
