from repro.sharding.compat import axis_types_kwargs, make_mesh, shard_map
from repro.sharding.policies import (batch_specs, cache_specs, named,
                                     param_specs, specee_specs, state_specs)
