"""Shard context — the one hashable handle the decode hot path threads.

``ShardCtx`` rides through the jitted strategy step as a STATIC argument
(``jax.sharding.Mesh`` hashes by device assignment + axis names), so the
exit-gate entry points can open a ``shard_map`` region over the mesh's
tensor-parallel axis without the engine layers knowing anything about
partitioning beyond "a mesh is active". Threading it explicitly (rather
than ambient module state) matters because ``verify_argmax``/``verify_topk``
are module-level jits shared by every Engine in the process: the mesh must
key their compilation caches.

Leaf module on purpose: imports jax only, so the kernel wrappers can use it
without dragging in the model/policy stack (ops.py -> policies -> model
would be an import cycle).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from jax.sharding import Mesh


@dataclass(frozen=True)
class ShardCtx:
    """Tensor-parallel context for the decode step.

    mesh: the serving mesh; ``axis`` names the dimension vocab/head dims
        shard over. Other mesh axes (e.g. a trivial 'data' axis) must hold
        the decode state replicated — the sharded-verify region only
        partitions along ``axis``.
    """
    mesh: Mesh
    axis: str = "model"

    @property
    def degree(self) -> int:
        return int(self.mesh.shape[self.axis])

    @staticmethod
    def from_mesh(mesh: Optional[Mesh],
                  axis: str = "model") -> Optional["ShardCtx"]:
        """None / missing axis / degree-1 mesh -> None (sharding inactive),
        so every caller can treat ``shard is None`` as the single-device
        path and a (1, 1) mesh costs nothing."""
        if mesh is None or axis not in mesh.shape or mesh.shape[axis] <= 1:
            return None
        return ShardCtx(mesh=mesh, axis=axis)
