"""Sharding policies: logical roles -> PartitionSpec, divisibility-aware.

Policies (ShardingConfig.policy):
  tp_dp   — serving, ≤30 GB-bf16 archs: weights TP over 'model', replicated
            over 'data'/'pod'; batch over ('pod','data').
  tp2d    — serving, big archs (command-r-plus, dbrx, qwen3, llama2-70b):
            2-D weight sharding — TP dim over 'model' AND the other matrix
            dim over 'data' so 100B+ weights fit 16 GB HBM chips.
  fsdp_tp — training (all archs): the tp_dp layout plus ZeRO-3: every
            remaining unsharded weight dim shards over 'data'; optimizer
            state inherits the parameter spec; batch over ('pod','data').

The Megatron roles: column-parallel = {wq, wk, wv, mlp-in/gate, router,
expert-in}, row-parallel = {wo, mlp-down, expert-down}, vocab-parallel =
{embedding, lm_head}. MoE expert stacks additionally shard the expert dim
over 'data' (EP). Any dim that does not divide its mesh extent falls back to
replicated (e.g. minicpm's odd 122753 vocab).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import RunConfig
from repro.models.model import Model


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if dim divides their product, else None (replicate)."""
    return axes if axes is not None and dim % _axes_size(mesh, axes) == 0 \
        else None


def _data_axes(mesh: Mesh) -> Any:
    return ("pod", "data") if "pod" in mesh.shape else "data"


class _Rules:
    """Path-string driven spec assignment for one (mesh, policy)."""

    def __init__(self, mesh: Mesh, policy: str):
        self.mesh = mesh
        self.policy = policy
        self.data = _data_axes(mesh)

    def _wrap(self, path: str, spec: P, leaf) -> P:
        """Stacked layer leaves carry a leading (reps,) dim -> prepend None."""
        if "segments" in path and len(spec) < np.ndim(leaf):
            return P(*((None,) + tuple(spec)))
        return spec

    def _second(self, dim: int):
        """The non-TP matrix dim: 'data' for tp2d/fsdp_tp if it divides."""
        if self.policy in ("tp2d", "fsdp_tp"):
            return _fit(self.mesh, dim, self.data)
        return None

    def param_spec(self, path: str, leaf) -> P:
        mesh = self.mesh
        shape = np.shape(leaf)
        m = "model"

        def col(din, dout):  # column-parallel (D_in, D_out-TP)
            return P(self._second(din), _fit(mesh, dout, m))

        def row(din, dout):  # row-parallel (D_in-TP, D_out)
            return P(_fit(mesh, din, m), self._second(dout))

        if path.endswith("embed/tok"):
            V, D = shape[-2:]
            v_ax = _fit(mesh, V, m)
            # odd vocabs (minicpm, internvl2): shard D over model instead;
            # never shard the embedding D over 'data' — the gather output
            # would drag activations away from batch sharding
            d_ax = None if v_ax is not None else _fit(mesh, D, m)
            if self.policy == "fsdp_tp" and v_ax is None and d_ax is None:
                v_ax = _fit(mesh, V, self.data)
            return P(v_ax, d_ax)
        if "lm_head" in path:
            D, V = shape[-2:]
            return P(self._second(D), _fit(mesh, V, m))
        # --- MoE expert stacks: (E, din, dout), EP over data ---
        # EP stays WITHIN a pod (pure DP across pods): when E doesn't divide
        # (pod×data) — dbrx's 16 experts on the 2×16×16 mesh — fall back to
        # the single 'data' axis rather than replicating 130B of experts
        if "moe" in path:
            def e_ax(E):
                return _fit(mesh, E, self.data) or _fit(mesh, E, "data")
            if path.endswith("router/w"):
                return self._wrap(path, P(None, None), leaf)
            if any(path.endswith(s) for s in ("moe/wi", "moe/wg")):
                E, D, F = shape[-3:]
                return self._wrap(
                    path, P(e_ax(E), None, _fit(mesh, F, m)), leaf)
            if path.endswith("moe/wo"):
                E, F, D = shape[-3:]
                return self._wrap(
                    path, P(e_ax(E), _fit(mesh, F, m), None), leaf)
        # --- attention ---
        if path.endswith(("wq/w", "wk/w", "wv/w")):
            din, dout = shape[-2:]
            return self._wrap(path, col(din, dout), leaf)
        if path.endswith("attn/wo/w") or path.endswith("wo/w"):
            din, dout = shape[-2:]
            return self._wrap(path, row(din, dout), leaf)
        for name in ("wq/b", "wk/b", "wv/b"):
            if path.endswith(name):
                return self._wrap(path, P(_fit(mesh, shape[-1], m)), leaf)
        # --- dense MLP ---
        for name in ("mlp/wi/w", "mlp/wg/w"):
            if path.endswith(name):
                din, dout = shape[-2:]
                return self._wrap(path, col(din, dout), leaf)
        if path.endswith("mlp/wo/w"):
            din, dout = shape[-2:]
            return self._wrap(path, row(din, dout), leaf)
        for name in ("mlp/wi/b", "mlp/wg/b"):
            if path.endswith(name):
                return self._wrap(path, P(_fit(mesh, shape[-1], m)), leaf)
        # --- RG-LRU ---
        for name in ("rec/wx/w", "rec/wy/w"):
            if path.endswith(name):
                din, dout = shape[-2:]
                return self._wrap(path, col(din, dout), leaf)
        if path.endswith("rec/wo/w"):
            din, dout = shape[-2:]
            return self._wrap(path, row(din, dout), leaf)
        for name in ("rec/wa/w", "rec/wi/w"):
            if path.endswith(name):
                # (W, W) gate matrices: TP the output dim
                din, dout = shape[-2:]
                return self._wrap(path, col(din, dout), leaf)
        for name in ("rec/wa/b", "rec/wi/b", "rec/lam", "rec/conv_w",
                     "rec/conv_b"):
            if path.endswith(name):
                return self._wrap(path, P(*([None] * (np.ndim(leaf) - 2)),
                                          _fit(mesh, shape[-1], m))
                                  if np.ndim(leaf) >= 1 else P(), leaf)
        # --- SSD (mamba2) ---
        if path.endswith("ssd/in_proj/w"):
            din, dout = shape[-2:]
            return self._wrap(path, col(din, dout), leaf)
        if path.endswith("ssd/out_proj/w"):
            din, dout = shape[-2:]
            return self._wrap(path, row(din, dout), leaf)
        # everything else (norms, small vectors, conv kernels, frontend):
        # replicate; fsdp shards the largest dim over data if it divides
        if self.policy == "fsdp_tp" and np.ndim(leaf) >= 1:
            dims = [None] * np.ndim(leaf)
            core = int(np.argmax(shape))
            if "segments" in path and np.ndim(leaf) > 1 and core == 0:
                core = 1 + int(np.argmax(shape[1:]))
            ax = _fit(self.mesh, shape[core], self.data)
            if ax is not None and shape[core] >= 1024:
                dims[core] = ax
            return P(*dims)
        return P(*([None] * np.ndim(leaf)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _map_with_paths(tree, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [fn(_path_str(path), leaf) for path, leaf in flat])


def param_specs(model: Model, mesh: Mesh, policy: str, params_shape) -> Any:
    """PartitionSpec pytree for model parameters (shapes from eval_shape)."""
    rules = _Rules(mesh, policy)
    return _map_with_paths(params_shape,
                           lambda p, l: rules.param_spec(p, l))


def state_specs(mesh: Mesh, policy: str, param_spec_tree, opt_shape) -> Any:
    """Optimizer state: m/v inherit the parameter spec; step replicated."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(),
                      m=param_spec_tree, v=param_spec_tree)


def batch_specs(model: Model, mesh: Mesh, batch_shape,
                seq_shard: bool = True) -> Any:
    """Input batch: batch dim over ('pod','data'); sequence dim over 'model'
    (Megatron-style sequence parallelism — the residual stream then lives
    sharded over TP, cutting per-device activation memory by the TP degree;
    GSPMD inserts the all-gather before attention / reduce-scatter after)."""
    data = _data_axes(mesh)

    def one(path, leaf):
        shape = np.shape(leaf)
        nd = np.ndim(leaf)
        ax = _fit(mesh, shape[0], data)
        # fall back to the single 'data' axis if (pod×data) doesn't divide
        if ax is None and not isinstance(data, str):
            ax = _fit(mesh, shape[0], "data")
        dims: List[Any] = [ax] + [None] * (nd - 1)
        if seq_shard and nd >= 2 and shape[1] >= 1024:
            dims[1] = _fit(mesh, shape[1], "model")
        return P(*dims)

    return _map_with_paths(batch_shape, one)


def cache_specs(model: Model, mesh: Mesh, policy: str, cache_shape,
                kv_seq_shard: bool = True) -> Any:
    """KV/state caches.

    Attention k/v: (reps, B, S, KVH, hd): B over data; then the first of
    {KVH, hd, S} that divides 'model' (S only if kv_seq_shard — the
    flash-decoding split-KV layout). Recurrent/SSM states: B over data, the
    widest state dim over 'model'.
    """
    data = _data_axes(mesh)

    def one(path, leaf):
        shape = np.shape(leaf)
        nd = np.ndim(leaf)
        if path.endswith("len"):
            return P()
        if nd == 0:
            return P()
        if path.endswith("/k") or path.endswith("/v"):
            has_reps = "segments" in path and nd == 5
            off = 1 if has_reps else 0  # (B, S, KVH, hd) core
            B, S, KVH, hd = shape[off:off + 4]
            dims: List[Any] = [None] * nd
            dims[off] = _fit(mesh, B, data) or _fit(mesh, B, "data")
            if _fit(mesh, KVH, "model"):
                dims[off + 2] = "model"
            elif kv_seq_shard and _fit(mesh, S, "model"):
                # GQA with kv_heads < TP degree: shard the SEQUENCE dim —
                # flash-decoding split-KV. Attention contracts hd locally,
                # softmax renormalization costs a scalar-sized AR instead of
                # gathering GBs of head_dim-sharded cache per layer
                dims[off + 1] = "model"
            elif _fit(mesh, hd, "model"):
                dims[off + 3] = "model"
            return P(*dims)
        # recurrent / conv / ssm states: (reps?, B, ...)
        off = 1 if ("segments" in path and nd >= 3) else 0
        dims = [None] * nd
        if nd > off:
            dims[off] = _fit(mesh, shape[off], data) or _fit(mesh, shape[off],
                                                             "data")
        # widest trailing dim over model
        if nd > off + 1:
            tail = int(np.argmax(shape[off + 1:])) + off + 1
            if _fit(mesh, shape[tail], "model") and shape[tail] >= 128:
                dims[tail] = "model"
        return P(*dims)

    return _map_with_paths(cache_shape, one)


def specee_specs(model: Model, mesh: Mesh, policy: str, sw_shape) -> Any:
    """SpecEE weights: draft layer shards like a TP block; predictors and the
    offline mask are tiny -> replicated."""
    rules = _Rules(mesh, policy if policy != "fsdp_tp" else "tp_dp")

    def one(path, leaf):
        if "draft" in path:
            # draft blocks reuse attention/mlp naming -> same rules, but no
            # leading stacked dim
            spec = rules.param_spec(path, leaf)
            return spec
        return P(*([None] * np.ndim(leaf)))

    return _map_with_paths(sw_shape, one)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
