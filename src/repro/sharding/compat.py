"""Version-portability shims for the jax.sharding surface.

Newer jax exposes ``jax.sharding.AxisType`` (explicit-sharding mesh axis
semantics), a ``jax.make_mesh(..., axis_types=...)`` kwarg, and a top-level
``jax.shard_map``. jax<=0.4.x has none of the three — every mesh / shard_map
construction in repro (and the subprocess test scripts) goes through these
helpers so the same code runs on both.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n}`` when supported, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              devices: Optional[Sequence[Any]] = None):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    kwargs = axis_types_kwargs(len(axis_names))
    if devices is not None:
        kwargs["devices"] = devices
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    except TypeError:
        # AxisType exists but make_mesh predates the kwarg (or vice versa)
        kwargs.pop("axis_types", None)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (0.4.x)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off — required when the body
    contains ops without a replication rule (``pallas_call``). The kwarg is
    ``check_rep`` on 0.4.x and ``check_vma`` on newer jax; try both, and fall
    back to the default checker if neither name exists."""
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return shard_map(f, mesh, in_specs, out_specs, **kw)
        except TypeError:
            continue
