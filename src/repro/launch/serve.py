"""Production serving launcher: continuous batching over the unified
decode API (``repro.api``) with SpecEE as the default fast path.

Smoke usage (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --requests 8
    PYTHONPATH=src python -m repro.launch.serve --mode tree --requests 4

The serving engine defaults the fused exit-gate pipeline ON
(serve-path adoption; pass --no-fused-gate to pin the reference path).
The full-scale path is the same strategy step jit'd against the production
mesh (see launch/dryrun.py, which lowers exactly this serve step for every
assigned architecture × decode shape).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--mode", default="specee",
                    choices=["specee", "dense", "tree"],
                    help="decode strategy behind the serving engine")
    ap.add_argument("--no-specee", action="store_true",
                    help="alias for --mode dense (back-compat)")
    ap.add_argument("--no-fused-gate", action="store_true",
                    help="pin the reference (unfused) exit-gate path")
    ap.add_argument("--cache", default="paged", choices=["paged", "dense"],
                    help="KV cache layout (paged pools vs the dense "
                         "slot-masked reference)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged-KV page size (default: ServeConfig.page_size)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked-prefill budget per tick "
                         "(0 = blocking admission; default: "
                         "ServeConfig.prefill_chunk)")
    ap.add_argument("--megatick", type=int, default=1,
                    help="decode ticks fused into one device-resident "
                         "lax.while_loop dispatch (1 = historical per-tick "
                         "host sync); > 1 also pipelines serving ticks "
                         "(async dispatch-ahead)")
    ap.add_argument("--sync-ticks", action="store_true",
                    help="disable the async serving pipeline even with "
                         "--megatick > 1")
    ap.add_argument("--ci", action="store_true",
                    help="CI smoke: few short requests + completion asserts")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for --mode dense "
                         "(0 = greedy)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for the session (--temperature > 0)")
    ap.add_argument("--trained", action="store_true",
                    help="train draft+predictors first (slower start)")
    args = ap.parse_args()
    mode = "dense" if args.no_specee else args.mode
    if args.ci:
        args.requests = min(args.requests, 4)
        args.max_new = min(args.max_new, 6)

    from repro.configs import get_config
    from repro.core import engine as eng
    from repro.models.model import build_model
    from repro.serving import ServingEngine

    if args.trained:
        from benchmarks.common import get_bundle
        b = get_bundle(args.arch)
        model, params, sw = b.model, b.params, b.sw
        run = b.run
    else:
        run = get_config(args.arch).smoke()
        model = build_model(run)
        params = model.init(jax.random.PRNGKey(0))
        sw = eng.init_specee(model, jax.random.PRNGKey(1))

    strategy = mode
    if args.temperature > 0.0:
        if mode != "dense":
            ap.error("--temperature requires --mode dense (SpecEE "
                     "verification is argmax-defined; see ROADMAP)")
        from repro.api import DenseStrategy
        strategy = DenseStrategy(temperature=args.temperature)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, run.model.vocab_size,
                            int(rng.integers(4, 16)))
               for _ in range(args.requests)]

    def run_engine(megatick: int):
        engine = ServingEngine(model, params, sw, strategy=strategy,
                               prng_seed=args.seed,
                               fused_gate=not args.no_fused_gate,
                               cache=args.cache, page_size=args.page_size,
                               prefill_chunk=args.prefill_chunk,
                               megatick=megatick,
                               async_ticks=False if args.sync_ticks else None)
        for p in prompts:
            engine.submit(p, max_new_tokens=args.max_new)
        t0 = time.perf_counter()
        done = engine.run_to_completion()
        return engine, done, time.perf_counter() - t0

    engine, done, dt = run_engine(args.megatick)
    toks = sum(len(r.output) for r in done)
    mgr = engine.session.cache_mgr
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, mode={mode}, cache={mgr.kind}, "
          f"chunk={engine.scheduler.chunk_tokens}, "
          f"megatick={args.megatick}, async={engine.async_ticks}, "
          f"fused_gate={not args.no_fused_gate})")
    if args.ci:
        assert len(done) == args.requests, \
            f"CI smoke: {len(done)}/{args.requests} requests completed"
        assert all(r.done and len(r.output) == args.max_new for r in done), \
            "CI smoke: a request missed its token budget"
        if mgr.kind == "paged":
            assert mgr.free_pages == mgr.num_pages, \
                f"CI smoke: page leak ({mgr.free_pages}/{mgr.num_pages} free)"
        if args.megatick > 1:
            # token parity: the fused K-tick while_loop + async pipeline
            # must emit exactly what the per-tick host-synced loop emits
            ref_engine, ref_done, _ = run_engine(1)
            got = {r.uid: r.output for r in done}
            ref = {r.uid: r.output for r in ref_done}
            assert got == ref, \
                f"CI smoke: megatick={args.megatick} tokens diverge from " \
                "megatick=1"
            ref_mgr = ref_engine.session.cache_mgr
            if ref_mgr.kind == "paged":
                assert ref_mgr.free_pages == ref_mgr.num_pages, \
                    "CI smoke: page leak in the megatick=1 reference"
            print(f"[serve] CI smoke OK (megatick={args.megatick} "
                  "token-parity with megatick=1)")
        else:
            print("[serve] CI smoke OK (paged-cache scheduler path "
                  "exercised)" if mgr.kind == "paged"
                  else "[serve] CI smoke OK")
    for r in done:
        line = (f"  req {r.uid}: {len(r.output)} tokens "
                f"exits={sum(1 for e in r.exit_points if e < model.num_exit_points)}")
        if mode == "tree":
            line += f" accepted={sum(r.accept_lens)}"
        print(line)


if __name__ == "__main__":
    main()
