"""Production serving launcher: continuous batching over the unified
decode API (``repro.api``) with SpecEE as the default fast path.

Smoke usage (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --requests 8
    PYTHONPATH=src python -m repro.launch.serve --mode tree --requests 4

Fault tolerance (DESIGN.md §7):
    --checkpoint-dir D   arm SIGTERM preemption: the engine drains, saves a
                         step-atomic snapshot into D, and exits with code 17
    --restore            resume the latest snapshot in D token-identically
    --inject SITE        deterministic fault injection at one named site
                         (dispatch / finish_timeout / nan_logits /
                         pool_exhausted / sigterm / device_lost) — the run
                         must still complete every request, and --ci
                         verifies the outputs against an in-process
                         fault-free reference. ``device_lost`` needs a
                         tensor-parallel mesh (--mesh 1,2): the engine
                         remeshes to a lower TP degree (DESIGN.md §10)
    --fault-log PATH     dump the engine's FaultEvent ring to PATH as JSONL
                         after the run (the machine-readable post-mortem)
    --num-pages N        oversubscribe the paged pool (fewer pages than
                         max_batch rows need) to drive victim eviction

The serving engine defaults the fused exit-gate pipeline ON
(serve-path adoption; pass --no-fused-gate to pin the reference path).
The full-scale path is the same strategy step jit'd against the production
mesh (see launch/dryrun.py, which lowers exactly this serve step for every
assigned architecture × decode shape).
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import numpy as np

PREEMPTED_EXIT_CODE = 17


def main() -> None:
    from repro.runtime import faultinject

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--mode", default="specee",
                    choices=["specee", "dense", "tree"],
                    help="decode strategy behind the serving engine")
    ap.add_argument("--no-specee", action="store_true",
                    help="alias for --mode dense (back-compat)")
    ap.add_argument("--no-fused-gate", action="store_true",
                    help="pin the reference (unfused) exit-gate path")
    ap.add_argument("--cache", default="paged", choices=["paged", "dense"],
                    help="KV cache layout (paged pools vs the dense "
                         "slot-masked reference)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged-KV page size (default: ServeConfig.page_size)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged-KV pool size in pages (default: capacity "
                         "parity with dense; smaller oversubscribes the "
                         "pool and exercises victim eviction)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked-prefill budget per tick "
                         "(0 = blocking admission; default: "
                         "ServeConfig.prefill_chunk)")
    ap.add_argument("--megatick", type=int, default=1,
                    help="decode ticks fused into one device-resident "
                         "lax.while_loop dispatch (1 = historical per-tick "
                         "host sync); > 1 also pipelines serving ticks "
                         "(async dispatch-ahead)")
    ap.add_argument("--sync-ticks", action="store_true",
                    help="disable the async serving pipeline even with "
                         "--megatick > 1")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="arm SIGTERM preemption: drain + snapshot here, "
                         f"exit {PREEMPTED_EXIT_CODE}; restart with "
                         "--restore to resume")
    ap.add_argument("--restore", action="store_true",
                    help="resume the latest checkpoint in --checkpoint-dir "
                         "(no-op on an empty directory)")
    ap.add_argument("--inject", default=None,
                    choices=list(faultinject.SITES),
                    help="deterministically inject one fault at the named "
                         "site; the run must still complete (recovery path)")
    ap.add_argument("--fault-log", default=None, metavar="PATH",
                    help="write the FaultEvent recovery trail to PATH as "
                         "JSONL after the run (engine + pool + per-replica "
                         "sources in one file)")
    ap.add_argument("--ci", action="store_true",
                    help="CI smoke: few short requests + completion asserts")
    ap.add_argument("--ticks-per-check", type=int, default=1,
                    help="(reserved) serving ticks between health checks")
    ap.add_argument("--quant", default=None, choices=["int8", "int4"],
                    help="weight-only compression (repro.quant): quantize "
                         "the LM head, predictor bank, and attention/MLP "
                         "projections; dequant is fused into the decode "
                         "kernels (the fp params stay untouched)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for --mode dense "
                         "(0 = greedy)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for the session (--temperature > 0)")
    ap.add_argument("--trained", action="store_true",
                    help="train draft+predictors first (slower start)")
    ap.add_argument("--mesh", default="1,1", metavar="DATA,MODEL",
                    help="decode mesh shape; MODEL > 1 turns on tensor-"
                         "parallel decode (DESIGN.md §9). Without real "
                         "accelerators the launcher forces host devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N) so CPU smoke runs exercise the same "
                         "sharded program")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel ServingEngine replicas behind one "
                         "shared queue (ReplicaPool); each replica gets its "
                         "own disjoint MODEL-wide device slice")
    args = ap.parse_args()
    try:
        data_par, model_par = (int(x) for x in args.mesh.split(","))
    except ValueError:
        ap.error(f"--mesh must be DATA,MODEL ints, got {args.mesh!r}")
    if data_par != 1:
        ap.error("--mesh DATA must be 1: data parallelism is --replicas "
                 "(independent engines), not an in-engine mesh axis")
    if model_par < 1 or args.replicas < 1:
        ap.error("--mesh MODEL and --replicas must be >= 1")
    need_devices = args.replicas * model_par
    if need_devices > 1:
        # host-mesh fallback: must land in XLA_FLAGS before the first jax
        # backend touch (the heavy imports below). A real TPU/GPU fleet is
        # unaffected — the flag only multiplies the CPU platform.
        import os
        import re
        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
        if m is None or int(m.group(1)) < need_devices:
            flags = re.sub(
                r"--xla_force_host_platform_device_count=\d+", "", flags)
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{need_devices}").strip()
    mode = "dense" if args.no_specee else args.mode
    if args.ci:
        args.requests = min(args.requests, 4)
        args.max_new = min(args.max_new, 6)
    if args.restore and not args.checkpoint_dir:
        ap.error("--restore requires --checkpoint-dir")
    if args.inject == "sigterm" and not args.checkpoint_dir:
        # the injected preemption is recovered in-process, which needs
        # somewhere to put the checkpoint
        args.checkpoint_dir = tempfile.mkdtemp(prefix="serve-ckpt-")
    if args.inject == "device_lost" and model_par <= 1:
        ap.error("--inject device_lost needs a tensor-parallel mesh to "
                 "degrade (e.g. --mesh 1,2): an unsharded engine has no "
                 "surviving devices to remesh onto and the fault is "
                 "terminal")
    if args.replicas > 1 and (args.checkpoint_dir or args.restore
                              or args.inject is not None):
        ap.error("--replicas composes with in-pool failover (a dead "
                 "replica's requests migrate to survivors), not with the "
                 "single-engine --checkpoint-dir/--restore/--inject paths")

    # arm SIGTERM before the heavy startup (jax import + model build +
    # tracing can run for minutes): a preemption landing mid-build must
    # defer to the first serve tick — which drains, saves, and exits
    # cleanly — not kill the process with the default handler
    guard = None
    if args.checkpoint_dir:
        from repro.runtime.fault import PreemptionGuard
        guard = PreemptionGuard()
        guard.install()

    from repro.api import CacheSpec
    from repro.configs import get_config
    from repro.core import engine as eng
    from repro.models.model import build_model
    from repro.runtime.faultinject import FaultSchedule
    from repro.serving import Preempted, ServingEngine

    if args.trained:
        from benchmarks.common import get_bundle
        b = get_bundle(args.arch)
        model, params, sw = b.model, b.params, b.sw
        run = b.run
    else:
        run = get_config(args.arch).smoke()
        model = build_model(run)
        params = model.init(jax.random.PRNGKey(0))
        sw = eng.init_specee(model, jax.random.PRNGKey(1))

    strategy = mode
    if args.temperature > 0.0:
        if mode != "dense":
            ap.error("--temperature requires --mode dense (SpecEE "
                     "verification is argmax-defined; see ROADMAP)")
        from repro.api import DenseStrategy
        strategy = DenseStrategy(temperature=args.temperature)
    cache = args.cache
    if args.num_pages is not None:
        if args.cache != "paged":
            ap.error("--num-pages requires --cache paged")
        cache = CacheSpec(kind="paged",
                          page_size=(args.page_size if args.page_size
                                     else run.serve.page_size),
                          num_pages=args.num_pages)
    # prompts are a pure function of the CLI, so a restarted --restore run
    # (and the in-process parity reference) regenerates the same workload
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, run.model.vocab_size,
                            int(rng.integers(4, 16)))
               for _ in range(args.requests)]

    def make_engine(megatick: int, checkpoint_dir=None, mesh=None):
        return ServingEngine(model, params, sw, strategy=strategy,
                             prng_seed=args.seed,
                             fused_gate=not args.no_fused_gate,
                             cache=cache, page_size=args.page_size,
                             prefill_chunk=args.prefill_chunk,
                             megatick=megatick,
                             async_ticks=False if args.sync_ticks else None,
                             checkpoint_dir=checkpoint_dir,
                             guard=guard if checkpoint_dir else None,
                             quant=args.quant, mesh=mesh)

    def run_engine(megatick: int, checkpoint_dir=None, restore=False,
                   mesh=None):
        engine = make_engine(megatick, checkpoint_dir=checkpoint_dir,
                             mesh=mesh)
        restored = restore and engine.restore_checkpoint()
        if restored:
            print(f"[serve] restored tick {engine._tick} from "
                  f"{checkpoint_dir} ({len(engine.completed)} requests "
                  "already complete)")
        else:
            for p in prompts:
                engine.submit(p, max_new_tokens=args.max_new)
        t0 = time.perf_counter()
        try:
            engine.run_to_completion()
        except Preempted as p:
            if args.inject == "sigterm":
                # injected preemption: recover in-process — exactly what a
                # restarted --restore process would do
                print(f"[serve] {p}; recovering in-process")
                engine.close()
                return run_engine(megatick, checkpoint_dir=checkpoint_dir,
                                  restore=True)
            print(f"[serve] {p}")
            engine.close()
            sys.exit(PREEMPTED_EXIT_CODE)
        engine.close()
        return engine, time.perf_counter() - t0

    schedule = None
    if args.inject == "pool_exhausted":
        schedule = FaultSchedule.at(pool_exhausted=range(8))
    elif args.inject == "sigterm":
        schedule = FaultSchedule.once("sigterm", visit=2)
    elif args.inject is not None:
        schedule = FaultSchedule.once(args.inject, visit=1)
    inj = faultinject.install(schedule) if schedule else None

    # ----- data-parallel replica pool (--replicas R) -----
    if args.replicas > 1:
        from repro.launch.mesh import make_replica_meshes
        from repro.serving import ReplicaPool
        meshes = make_replica_meshes(args.replicas, model_par)
        pool = ReplicaPool([make_engine(args.megatick, mesh=ms)
                            for ms in meshes])
        prs = [pool.submit(p, max_new_tokens=args.max_new) for p in prompts]
        t0 = time.perf_counter()
        pool.run_to_completion()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in pool.completed)
        print(f"[serve] {len(pool.completed)} requests, {toks} tokens in "
              f"{dt:.2f}s ({toks/dt:.1f} tok/s, replicas={args.replicas}, "
              f"mesh=(1,{model_par}), mode={mode}, "
              f"megatick={args.megatick})")
        if args.ci:
            assert len(pool.completed) == args.requests, \
                f"CI smoke: {len(pool.completed)}/{args.requests} completed"
            assert all(r.done and len(r.output) == args.max_new
                       for r in prs), \
                "CI smoke: a pooled request missed its token budget"
            ref_engine, _ = run_engine(1)
            ref = [r.output for r in sorted(ref_engine.completed,
                                            key=lambda r: r.uid)]
            got = [list(pr.output) for pr in prs]
            assert got == ref, \
                "CI smoke: pool tokens diverge from the single-engine " \
                "reference"
            print("[serve] CI smoke OK (replica-pool token parity with the "
                  "single-engine reference)")
        if args.fault_log:
            n = pool.fault_log.dump_jsonl(args.fault_log, source="pool")
            for i, rep in enumerate(pool.replicas):
                n += rep.fault_log.dump_jsonl(args.fault_log,
                                              source=f"replica{i}",
                                              append=True)
            print(f"[serve] fault log: {n} events -> {args.fault_log}")
        pool.close()
        return

    mesh = None
    if model_par > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, model_par)
    engine, dt = run_engine(args.megatick,
                            checkpoint_dir=args.checkpoint_dir,
                            restore=args.restore, mesh=mesh)
    faultinject.uninstall()
    done = engine.completed
    toks = sum(len(r.output) for r in done)
    mgr = engine.session.cache_mgr
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, mode={mode}, cache={mgr.kind}, "
          f"chunk={engine.scheduler.chunk_tokens}, "
          f"megatick={args.megatick}, async={engine.async_ticks}, "
          f"fused_gate={not args.no_fused_gate}, "
          f"quant={args.quant or 'fp'})")
    if inj is not None:
        assert args.inject in inj.fired_sites(), \
            f"--inject {args.inject} never fired (schedule {schedule.plan})"
        recovery = [(e.site, e.action) for e in engine.fault_log]
        print(f"[serve] injected {args.inject} at visits "
              f"{sorted(inj.schedule.plan[args.inject])}; recovery log: "
              f"{recovery}")
        if args.inject == "device_lost":
            # the loss must degrade IN PLACE, not crash: a remesh event in
            # the log and a TP degree strictly below the built mesh
            assert any(e.action == "remesh" for e in engine.fault_log), \
                "--inject device_lost: no remesh in the fault log"
            assert engine.tp_degree < model_par, \
                f"--inject device_lost: tp still {engine.tp_degree}"
            print(f"[serve] remeshed tp {model_par}->{engine.tp_degree} "
                  "(degraded mode, verified replay)")
    if args.fault_log:
        n = engine.fault_log.dump_jsonl(args.fault_log, source="engine")
        print(f"[serve] fault log: {n} events -> {args.fault_log}")
    if args.ci:
        assert len(done) == args.requests, \
            f"CI smoke: {len(done)}/{args.requests} requests completed"
        assert all(r.done and len(r.output) == args.max_new for r in done), \
            "CI smoke: a request missed its token budget"
        if mgr.kind == "paged":
            assert mgr.free_pages == mgr.num_pages, \
                f"CI smoke: page leak ({mgr.free_pages}/{mgr.num_pages} free)"
        # token parity: restored, fault-injected, eviction-pressured, and
        # fused/pipelined runs must all emit exactly what the plain
        # per-tick fault-free loop emits
        need_ref = (args.megatick > 1 or args.restore
                    or args.inject is not None or args.num_pages is not None
                    or model_par > 1)
        if need_ref:
            ref_engine, _ = run_engine(1)
            got = {r.uid: r.output for r in done}
            ref = {r.uid: r.output for r in ref_engine.completed}
            assert got == ref, \
                "CI smoke: tokens diverge from the fault-free megatick=1 " \
                "reference"
            ref_mgr = ref_engine.session.cache_mgr
            if ref_mgr.kind == "paged":
                assert ref_mgr.free_pages == ref_mgr.num_pages, \
                    "CI smoke: page leak in the megatick=1 reference"
            print("[serve] CI smoke OK (token-parity with the fault-free "
                  "megatick=1 reference)")
        else:
            print("[serve] CI smoke OK (paged-cache scheduler path "
                  "exercised)" if mgr.kind == "paged"
                  else "[serve] CI smoke OK")
    for r in done:
        line = (f"  req {r.uid}: {len(r.output)} tokens "
                f"exits={sum(1 for e in r.exit_points if e < model.num_exit_points)}")
        if r.evictions:
            line += f" evictions={r.evictions}"
        if mode == "tree":
            line += f" accepted={sum(r.accept_lens)}"
        print(line)


if __name__ == "__main__":
    main()
