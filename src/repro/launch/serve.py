"""Production serving launcher: continuous batching + SpecEE.

Smoke usage (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --requests 8

The full-scale path is the same engine jit'd against the production mesh
(see launch/dryrun.py, which lowers exactly this serve step for every
assigned architecture × decode shape).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--no-specee", action="store_true")
    ap.add_argument("--trained", action="store_true",
                    help="train draft+predictors first (slower start)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import engine as eng
    from repro.models.model import build_model
    from repro.serving import ServingEngine

    if args.trained:
        from benchmarks.common import get_bundle
        b = get_bundle(args.arch)
        model, params, sw = b.model, b.params, b.sw
        run = b.run
    else:
        run = get_config(args.arch).smoke()
        model = build_model(run)
        params = model.init(jax.random.PRNGKey(0))
        sw = eng.init_specee(model, jax.random.PRNGKey(1))

    engine = ServingEngine(model, params, sw, specee=not args.no_specee)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(rng.integers(0, run.model.vocab_size,
                                   int(rng.integers(4, 16))),
                      max_new_tokens=args.max_new)
    t0 = time.perf_counter()
    done = engine.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, specee={not args.no_specee})")
    for r in done:
        print(f"  req {r.uid}: {len(r.output)} tokens "
              f"exits={sum(1 for e in r.exit_points if e < model.num_exit_points)}")


if __name__ == "__main__":
    main()
