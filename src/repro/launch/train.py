"""Production training launcher.

Single-host usage (smoke / development):
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 100 --ckpt /tmp/ck

Cluster usage (per-host, under your pod scheduler):
    python -m repro.launch.train --arch dbrx-132b \
        --coordinator $COORD --num-hosts 64 --host-id $ID --ckpt gs://...

The launcher wires together the pieces the rest of the framework provides:
  * jax.distributed initialization (multi-host),
  * the production mesh + FSDP/TP shardings (repro.sharding),
  * sharded-jit train step with remat + grad accumulation (repro.train),
  * checkpoint/restart + preemption guard + straggler monitor (repro.runtime),
  * elastic re-mesh on degraded restarts (repro.runtime.fault.plan_remesh).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--data", type=int, default=0, help="data-parallel degree")
    ap.add_argument("--model", type=int, default=1, help="tensor-parallel degree")
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_hosts,
                                   process_id=args.host_id)

    from repro.configs import get_config
    from repro.models.model import ModelFlags, build_model
    from repro.runtime.fault import plan_remesh
    from repro.train import TrainLoop

    run = get_config(args.arch)
    if args.smoke:
        run = run.smoke()

    n_dev = len(jax.devices())
    mesh_shape = plan_remesh(n_dev, args.model)
    if mesh_shape is None:
        raise SystemExit(f"cannot build a mesh from {n_dev} devices at "
                         f"TP={args.model}")
    print(f"[launch] devices={n_dev} mesh={mesh_shape}")

    flags = ModelFlags(remat="full" if not args.smoke else "none",
                       act_batch_axes="data" if n_dev > 1 else None,
                       act_batch_extent=mesh_shape[0])
    model = build_model(run, flags)
    params = model.init(jax.random.PRNGKey(run.train.seed))

    loop = TrainLoop(model, run, params, ckpt_dir=args.ckpt,
                     host_id=args.host_id)
    loop.guard.install()
    if loop.try_restore():
        print(f"[launch] restored step {loop.step}")
    steps = args.steps if args.steps is not None else run.train.steps
    while loop.step < steps and not loop.guard.should_save():
        stats = loop.run_steps(min(10, steps - loop.step))
        print(f"[train] step={loop.step} loss={stats['loss']:.4f} "
              f"lr={stats['lr']:.2e} {stats['step_time']*1e3:.0f}ms "
              f"stragglers={loop.monitor.stragglers()}")
    if args.ckpt:
        loop.save()
        loop.ckpt.wait()


if __name__ == "__main__":
    main()
