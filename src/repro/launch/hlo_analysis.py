"""Trip-count-aware collective analysis of post-SPMD HLO text.

XLA's ``cost_analysis`` counts loop bodies once. This module rebuilds the
computation call graph from ``compiled.as_text()`` and walks it from ENTRY,
multiplying collective payload bytes by loop trip counts:

* while loops lowered from ``lax.scan`` carry their trip count as an s32
  constant inside the condition computation (compare against the iteration
  counter) — parsed directly;
* dynamic whiles (early-exit loops, pruned-attention fori with traced
  bounds) have no constant — a caller-supplied ``default_trip`` (the layer
  count = the full-depth upper bound) is used;
* conditionals count BOTH branches (upper bound — SpecEE's verification
  branch fires at most once per unit);
* fusions/calls/reductions multiply by 1.

The result is per-device collective bytes *per executed step*, the quantity
the roofline's collective term needs.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(
    r"\b(f32|f16|bf16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
_BYTES = {"f32": 4, "s32": 4, "u32": 4, "f16": 2, "bf16": 2, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}
_OP_RE = re.compile(r"=\s+(.*?)\s([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-{}, %]+?)\}?[,\s]")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _payload_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_computations(txt: str) -> Tuple[Dict[str, Dict], Optional[str]]:
    comps: Dict[str, Dict] = {}
    entry = None
    cur: Optional[str] = None
    for raw in txt.splitlines():
        s = raw.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            cur = m.group(2)
            comps[cur] = {"coll": {}, "children": []}
            if m.group(1):
                entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        c = comps[cur]
        mw = _WHILE_RE.search(s)
        if mw:
            cond, body = mw.group(1), mw.group(2)
            c["children"].append(("while", body, cond))
            continue
        # conditionals / calls / fusions
        mb = re.search(r"branch_computations=\{([^}]*)\}", s)
        if mb:
            for b in mb.group(1).split(","):
                c["children"].append(("call", b.strip().lstrip("%"), None))
        else:
            for key in ("calls=", "to_apply="):
                i = s.find(key)
                if i >= 0:
                    name = re.match(r"%?([\w\.\-]+)", s[i + len(key):])
                    if name:
                        c["children"].append(("call", name.group(1), None))
        mo = _OP_RE.search(s)
        if mo:
            op = mo.group(2)
            base = op.replace("-start", "")
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                c["coll"][base] = c["coll"].get(base, 0) + \
                    _payload_bytes(mo.group(1))
        # record constants for trip-count extraction
        mc = _CONST_RE.search(s)
        if mc:
            c.setdefault("consts", []).append(int(mc.group(1)))
    return comps, entry


def trip_count(comps: Dict[str, Dict], cond: str,
               default_trip: int) -> Tuple[int, bool]:
    """Trip count of a while from its condition computation's s32 constant.
    Returns (trips, known)."""
    c = comps.get(cond, {})
    consts = c.get("consts", [])
    if len(consts) == 1:
        return max(consts[0], 1), True
    if consts:
        return max(max(consts), 1), True
    return default_trip, False


def collective_totals(txt: str, default_trip: int = 1) -> Dict[str, Any]:
    comps, entry = parse_computations(txt)
    if entry is None:
        return {"total_bytes": 0.0, "by_op": {}, "unknown_trips": 0}
    totals: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    unknown = [0]

    from functools import lru_cache

    import sys
    sys.setrecursionlimit(10000)

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str, depth: int = 0) -> Dict[str, float]:
        """Per-single-execution collective bytes of computation ``name``."""
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return {}
        out = dict(c["coll"])
        memo[name] = out  # pre-set (cycle guard)
        for kind, child, cond in c["children"]:
            sub = walk(child, depth + 1)
            if kind == "while":
                trips, known = trip_count(comps, cond, default_trip)
                if not known:
                    unknown[0] += 1
                for k, v in sub.items():
                    out[k] = out.get(k, 0.0) + v * trips
            else:
                for k, v in sub.items():
                    out[k] = out.get(k, 0.0) + v
        memo[name] = out
        return out

    top = walk(entry)
    for k, v in top.items():
        totals[k] = totals.get(k, 0.0) + v
    return {"total_bytes": sum(totals.values()), "by_op": totals,
            "unknown_trips": unknown[0]}
