"""ShapeDtypeStruct stand-ins for every dry-run input (no allocation).

``input_specs(run, shape_cell)`` returns (fn_args, sharding_specs) for the
step function of that cell:
  train   -> (params_f32, opt_state, batch)        for train_step
  prefill -> (params_lowp, batch)                  for prefill_step
  decode  -> (params_lowp, specee_weights, state)  for serve_step (SpecEE AR)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import RunConfig, ShapeCell
from repro.core import draft as draft_lib
from repro.core import engine as eng
from repro.core import scheduler as sched_lib
from repro.data.pipeline import make_batch_specs
from repro.models.common import dtype_of
from repro.models.model import Model
from repro.optim.adamw import adamw_init
from repro.sharding import (batch_specs, cache_specs, param_specs,
                            specee_specs, state_specs)


def _cast_float(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating)
            else x.dtype), tree)


def params_struct(model: Model, low_precision: bool) -> Any:
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if low_precision:
        return _cast_float(shapes, dtype_of(model.cfg.dtype))
    return shapes


def batch_struct(model: Model, cell: ShapeCell) -> Dict[str, Any]:
    cfg = model.cfg
    seq = cell.seq_len
    if cfg.frontend == "vision_patches":
        seq = max(seq - cfg.frontend_tokens, 1)  # total length incl. patches
    spec = make_batch_specs(cfg, cell.global_batch, seq)
    return {k: jax.ShapeDtypeStruct(shape, dt) for k, (shape, dt) in
            spec.items()}


def decode_state_struct(model: Model, cell: ShapeCell) -> eng.DecodeState:
    cfg = model.cfg
    B, S = cell.global_batch, cell.seq_len

    def build():
        dtype = dtype_of(cfg.dtype)
        cache = model.empty_cache(B, S)
        # mark the cache as "full" semantically; shapes are what matter here
        dcache = draft_lib.draft_cache(cfg, B, S, dtype)
        return eng.DecodeState(
            cache=cache, draft_cache=dcache,
            sched=sched_lib.init_state(B, model.run.specee),
            last_token=jnp.zeros((B,), jnp.int32),
            h_last=jnp.zeros((B, cfg.d_model), dtype),
            prng=jax.random.PRNGKey(0))

    return jax.eval_shape(build)


def specee_struct(model: Model) -> eng.SpecEEWeights:
    sw = jax.eval_shape(lambda: eng.init_specee(model, jax.random.PRNGKey(0)))
    # draft runs in the serving dtype
    return eng.SpecEEWeights(
        draft=_cast_float(sw.draft, dtype_of(model.cfg.dtype)),
        predictors=sw.predictors, offline_mask=sw.offline_mask)


def input_specs(model: Model, cell: ShapeCell, mesh) -> Tuple[Tuple, Tuple]:
    """Returns (arg_structs, arg_partition_specs) for this cell's step fn."""
    policy_serve = model.run.sharding.policy
    if cell.kind == "train":
        params = params_struct(model, low_precision=False)
        pspec = param_specs(model, mesh, "fsdp_tp", params)
        opt = jax.eval_shape(adamw_init, params)
        ospec = state_specs(mesh, "fsdp_tp", pspec, opt)
        batch = batch_struct(model, cell)
        bspec = batch_specs(model, mesh, batch)
        return (params, opt, batch), (pspec, ospec, bspec)
    if cell.kind == "prefill":
        params = params_struct(model, low_precision=True)
        pspec = param_specs(model, mesh, policy_serve, params)
        batch = batch_struct(model, cell)
        bspec = batch_specs(model, mesh, batch)
        return (params, batch), (pspec, bspec)
    # decode
    params = params_struct(model, low_precision=True)
    pspec = param_specs(model, mesh, policy_serve, params)
    sw = specee_struct(model)
    swspec = specee_specs(model, mesh, policy_serve, sw)
    state = decode_state_struct(model, cell)
    data_ax = ("pod", "data") if "pod" in mesh.shape else "data"

    def fit(dim, ax):
        import numpy as _np
        size = (_np.prod([mesh.shape[a] for a in ax])
                if isinstance(ax, tuple) else mesh.shape[ax])
        return ax if dim % size == 0 else None

    B = cell.global_batch
    b_ax = fit(B, data_ax) or fit(B, "data")
    sspec = eng.DecodeState(
        cache=cache_specs(model, mesh, policy_serve, state.cache,
                          model.run.sharding.kv_seq_shard),
        draft_cache=cache_specs(model, mesh, policy_serve,
                                state.draft_cache,
                                model.run.sharding.kv_seq_shard),
        sched={"queue": P(b_ax, None), "qpos": P(b_ax)},
        last_token=P(b_ax),
        h_last=P(b_ax, None),
        prng=P(None),
    )
    return (params, sw, state), (pspec, swspec, sspec)
