"""Production mesh construction.

16×16 = 256 chips per v5e pod; the multi-pod mesh adds a leading 'pod' axis
(2 pods = 512 chips for the dry-run; the same code scales the pod extent).
Defined as functions — importing this module never touches jax device state.
"""
from __future__ import annotations

import math

import jax

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    devices = jax.devices()[: data * model]
    return make_mesh((data, model), ("data", "model"), devices=devices)
