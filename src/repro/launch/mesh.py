"""Production mesh construction.

16×16 = 256 chips per v5e pod; the multi-pod mesh adds a leading 'pod' axis
(2 pods = 512 chips for the dry-run; the same code scales the pod extent).
Defined as functions — importing this module never touches jax device state.
"""
from __future__ import annotations

import math

import jax

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    devices = jax.devices()[: data * model]
    if len(devices) < data * model:
        raise RuntimeError(
            f"mesh ({data},{model}) needs {data * model} devices, found "
            f"{len(devices)} — run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model}")
    return make_mesh((data, model), ("data", "model"), devices=devices)


def make_replica_meshes(replicas: int, model: int = 1):
    """Disjoint per-replica (1, model) meshes for a ``ReplicaPool``: replica
    i owns devices [i·model, (i+1)·model) — tensor parallelism within a
    replica, pure data parallelism (no collective) across them. ``model=1``
    with one device total returns ``[None] * replicas`` (replicas time-share
    the device — the CPU smoke-test degeneration)."""
    need = replicas * model
    devices = jax.devices()
    if model == 1 and len(devices) == 1:
        return [None] * replicas
    if len(devices) < need:
        raise RuntimeError(
            f"{replicas} replicas × model={model} need {need} devices, "
            f"found {len(devices)} — run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    return [make_mesh((1, model), ("data", "model"),
                      devices=devices[i * model:(i + 1) * model])
            for i in range(replicas)]
