import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell
on the production mesh and dump memory/cost/collective analysis.

MUST be the process entry point (device count locks on first jax init):

    REPRO_DRYRUN_DEVICES=256 python -m repro.launch.dryrun --arch llama2-7b \
        --shape decode_32k --out out.json
    REPRO_DRYRUN_DEVICES=512 python -m repro.launch.dryrun --multi-pod ...

(512 placeholder CPU devices exist ONLY here; tests/benches see 1 device.)
"""
import argparse
import json
import re
import sys
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DenseStrategy, SpecEEStrategy
from repro.config import RunConfig, ShapeCell, applicable_shapes, shape_by_name
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.model import Model, ModelFlags, build_model
from repro.train.loop import make_train_step

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"\b(f32|f16|bf16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
_BYTES = {"f32": 4, "s32": 4, "u32": 4, "f16": 2, "bf16": 2, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def step_fn_for(model: Model, run: RunConfig, cell: ShapeCell,
                dense_decode: bool = False, data_extent: int = 16,
                param_pspec=None):
    if cell.kind == "train":
        import dataclasses
        # gradient accumulation: microbatches bound activation memory; each
        # chunk keeps ≥1 row per data shard
        mb = max(cell.global_batch // 16, data_extent)
        tcfg = dataclasses.replace(run.train, global_batch=cell.global_batch,
                                   seq_len=cell.seq_len, microbatch=mb)
        return make_train_step(model, tcfg, param_pspec=param_pspec)
    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, cache, _ = model.prefill(params, batch,
                                             max_seq=cell.seq_len + 1)
            if cache is None:        # encoder arch
                return logits
            return logits, cache
        if not model.cfg.is_decoder():
            def encoder_step(params, batch):
                logits, _, _ = model.prefill(params, batch)
                return logits
            return encoder_step
        return prefill_step
    # decode: the SpecEE AR serve step (the paper's technique) or dense —
    # both through the unified strategy API (the same jittable step the
    # serving engine's DecodeSession drives)
    if run.specee.enabled and not dense_decode:
        strat = SpecEEStrategy()

        def serve_step(params, sw, state):
            res, new_state = strat.step(model, params, sw, state)
            return res.tokens, new_state, res.exit_layer
        return serve_step

    dense = DenseStrategy()

    def dense_serve_step(params, sw, state):
        res, new_state = dense.step(model, params, sw, state)
        return res.tokens, new_state
    return dense_serve_step


_OP_RE = re.compile(r"=\s+(.*?)\s([a-z][a-z0-9\-]*)\(")


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in post-SPMD HLO.

    XLA emits loop bodies as separate computations and cost analysis counts
    them ONCE, so we split collectives into ``entry_bytes`` (ENTRY %main,
    executes once) and ``loop_bytes`` (non-entry computations — scan/while
    bodies and their cond branches). The roofline scales loop_bytes by the
    analytically-known trip count of the layer loop (EXPERIMENTS.md §Roofline
    states the approximation: collectives nested in inner chunk loops are
    counted at layer-loop multiplicity).
    """
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    count: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    entry_bytes, loop_bytes = 0.0, 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        s = line.lstrip()
        if s.startswith("ENTRY "):
            in_entry = True
        elif s.startswith("%") and s.rstrip().endswith("{"):
            in_entry = False
        m = _OP_RE.search(s)
        if not m:
            continue
        op = m.group(2)
        if op not in COLLECTIVE_OPS:
            # async forms: count -start, skip -done (same payload)
            base = op.replace("-start", "")
            if base not in COLLECTIVE_OPS or op.endswith("-done"):
                continue
            op = base
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _BYTES[dt]
        out[op] += total
        count[op] += 1
        if in_entry:
            entry_bytes += total
        else:
            loop_bytes += total
    out["total_bytes"] = sum(out[k] for k in COLLECTIVE_OPS)
    out["entry_bytes"] = entry_bytes
    out["loop_bytes"] = loop_bytes
    out["counts"] = count  # type: ignore
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             flags: Optional[ModelFlags] = None, unroll: bool = False,
             dense_decode: bool = False) -> Dict[str, Any]:
    run = get_config(arch)
    cell = shape_by_name(shape_name)
    assert cell in applicable_shapes(run.model), \
        f"{shape_name} not applicable to {arch} (see DESIGN.md §4)"
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_extent = int(np.prod([v for k, v in mesh.shape.items()
                               if k in ("pod", "data")]))
    model = build_model(run, flags or ModelFlags(
        remat="full" if cell.kind == "train" else "none", unroll=unroll,
        act_batch_axes=("pod", "data") if multi_pod else "data",
        act_batch_extent=data_extent,
        # §Perf-confirmed default: pin the residual stream for dense-arch
        # training (−75% collectives, +1.5-4 GB temp — fits for d ≤ 8192;
        # MoE archs keep headroom for the gathered-token EP buffers)
        act_pin_full=(cell.kind == "train" and run.model.moe is None
                      and run.model.d_model <= 8192),
        # wide models: smaller attention/CE chunks bound fp32 score tensors
        chunk_size=256 if run.model.d_model >= 8192 else 512,
        ce_chunk=256 if run.model.d_model >= 8192 else 512))
    args, specs = input_specs(model, cell, mesh)
    fn = step_fn_for(model, run, cell, dense_decode=dense_decode,
                     data_extent=data_extent,
                     param_pspec=specs[0] if cell.kind == "train" else None)
    from repro.sharding.policies import named
    in_shardings = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    # buffer donation: decode donates the state so the KV cache updates in
    # place (the scan/while ping-pong otherwise doubles the 8 GB cache).
    # Train donation measured WORSE on the CPU-XLA buffer accounting
    # (params+opt aliasing blocked other reuse: +4..10 GB temp) — kept off.
    donate = (2,) if cell.kind == "decode" else ()
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    units = sum(reps for _, reps in model.segments)
    loop_scale = units
    if cell.kind == "train":
        mb = max(cell.global_batch // 16, data_extent)
        loop_scale = units * max(cell.global_batch // mb, 1)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(np.prod(list(mesh.shape.values()))),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "loop_scale": loop_scale, "units": units,
    }
    # ---- memory ----
    try:
        ma = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not expose it
        result["memory_error"] = str(e)
    # analytic per-device argument bytes from the shardings
    arg_bytes = 0
    for leafspec, leaf in zip(
            jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)),
            jax.tree_util.tree_leaves(args)):
        shard = 1
        for ax in jax.tree_util.tree_leaves(tuple(leafspec)):
            if ax is not None:
                shard *= mesh.shape[ax]
        arg_bytes += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // max(shard, 1)
    result["analytic_arg_bytes_per_device"] = arg_bytes
    # ---- cost ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        result["cost"] = {k: float(v) for k, v in ca.items()
                          if isinstance(v, (int, float)) and
                          k in ("flops", "bytes accessed",
                                "bytes accessed output", "optimal_seconds")}
    except Exception as e:
        result["cost_error"] = str(e)
    # ---- collectives ----
    try:
        txt = compiled.as_text()
        result["collectives"] = collective_bytes(txt)
        from repro.launch.hlo_analysis import collective_totals
        # trip-count-aware accounting; dynamic whiles (early-exit) bound by
        # the full unit count
        result["collectives_exact"] = collective_totals(txt,
                                                        default_trip=units)
        result["hlo_chars"] = len(txt)
        del txt
    except Exception as e:
        result["collectives_error"] = str(e)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--assigned-only", action="store_true",
                    help="skip the llama2 paper configs")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer loops (roofline-accurate FLOP counts)")
    ap.add_argument("--dense-decode", action="store_true",
                    help="lower the dense baseline serve step (no SpecEE)")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch != "all" else
             [a for a in ARCHS if not (args.assigned_only and
                                       a.startswith("llama2"))])
    results = []
    for arch in archs:
        run = get_config(arch)
        cells = applicable_shapes(run.model)
        names = ([args.shape] if args.shape != "all"
                 else [c.name for c in cells])
        for name in names:
            if name not in [c.name for c in cells]:
                print(f"SKIP {arch} {name} (inapplicable)", flush=True)
                continue
            print(f"=== {arch} × {name} × "
                  f"{'2x16x16' if args.multi_pod else '16x16'} ===",
                  flush=True)
            try:
                r = run_cell(arch, name, args.multi_pod, unroll=args.unroll,
                             dense_decode=args.dense_decode)
                print(json.dumps(
                    {k: r.get(k) for k in ("compile_s", "memory",
                                           "analytic_arg_bytes_per_device",
                                           "cost")},
                    default=str), flush=True)
            except Exception as e:
                r = {"arch": arch, "shape": name, "error": repr(e)}
                print("FAILED:", repr(e), flush=True)
            results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print("wrote", args.out)
    bad = [r for r in results if "error" in r]
    print(f"{len(results) - len(bad)}/{len(results)} cells compiled")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
