"""Step-atomic sharded checkpointing with async save and restart-from-latest.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json       — pytree structure, leaf shapes/dtypes, metadata
                              (data-pipeline state, mesh shape, config hash)
        shard_00000.npz     — flat leaves (chunked ≤ ``shard_bytes``)
        ...
        COMMITTED           — written LAST; a step dir without it is garbage

Crash-safety: writes go to ``step_X.tmp`` and are atomically renamed after
the COMMITTED marker lands, so a preempted save never corrupts the latest
good checkpoint. ``restore_latest`` skips uncommitted dirs. Async mode hands
the (host-materialized) arrays to a background thread — the train loop only
blocks on the previous save (one-deep pipeline, like Orbax async).

On a real multi-host pod each host writes only the shards it owns (addressable
data per device); here the single process owns everything, but the manifest
format already records per-leaf sharding specs so the restore path is
process-count independent.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = True,
                 shard_bytes: int = 256 * 1024 * 1024):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self.shard_bytes = shard_bytes
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ----- save -----
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()  # one-deep pipeline
        # materialize on host BEFORE handing off (device buffers may mutate)
        leaves, _ = _flatten_with_paths(tree)
        host = [(k, np.asarray(v)) for k, v in leaves]
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, tree, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, tree, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, tree, extra: Dict) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": [], "shards": []}
        shard, shard_sz, shard_id = {}, 0, 0

        def flush():
            nonlocal shard, shard_sz, shard_id
            if not shard:
                return
            fn = f"shard_{shard_id:05d}.npz"
            np.savez(os.path.join(tmp, fn), **shard)
            manifest["shards"].append(fn)
            shard, shard_sz = {}, 0
            shard_id += 1

        for i, (key, arr) in enumerate(host_leaves):
            name = f"leaf_{i:06d}"
            manifest["leaves"].append({
                "key": key, "name": name, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "shard": len(manifest["shards"])})
            shard[name] = arr
            shard_sz += arr.nbytes
            if shard_sz >= self.shard_bytes:
                flush()
        flush()
        # fix shard index for leaves flushed late
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # ----- restore -----
    def all_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.root)):
            p = os.path.join(self.root, d)
            if (d.startswith("step_") and not d.endswith(".tmp")
                    and os.path.exists(os.path.join(p, "COMMITTED"))):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (shape/dtype validated)."""
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shards = [np.load(os.path.join(d, fn)) for fn in manifest["shards"]]
        leaves, treedef = _flatten_with_paths(like)
        assert len(leaves) == len(manifest["leaves"]), \
            f"leaf count mismatch: {len(leaves)} vs {len(manifest['leaves'])}"
        vals = []
        for (key, ref), meta in zip(leaves, manifest["leaves"]):
            arr = shards[meta["shard"]][meta["name"]]
            assert list(np.shape(ref)) == meta["shape"], \
                f"{key}: shape {np.shape(ref)} != saved {meta['shape']}"
            vals.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), vals)
        return tree, manifest["extra"]

    def restore_latest(self, like: Any) -> Optional[Tuple[int, Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like)
        return step, tree, extra
