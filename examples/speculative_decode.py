"""T3 showcase: tree speculative decoding with hyper-token early exiting.

    PYTHONPATH=src python examples/speculative_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.common import get_bundle
from repro.core import engine as eng
from repro.core.tree import TreeSpec


def main():
    b = get_bundle()
    m, params, sw = b.model, b.params, b.sw
    tree = TreeSpec(depth=2, branch=3)
    print(f"token tree: {tree.num_nodes} nodes, "
          f"{tree.path_nodes.shape[0]} hyper-token paths "
          f"(mapping complexity is LINEAR in paths — paper §6)")

    prompt = jnp.arange(10)[None, :] % b.run.model.vocab_size
    first, st = eng.init_tree_decode_state(m, params, sw,
                                           {"tokens": prompt}, 96, tree)
    emitted = [int(first[0])]
    for step in range(10):
        out, n, st, info = eng.tree_decode_step(m, params, sw, st, tree)
        new = [int(x) for x in out[0, :int(n[0])]]
        emitted.extend(new)
        print(f"step {step}: accepted {int(info.accepted_len[0])} draft "
              f"tokens + bonus -> {new} "
              f"(exit {int(info.exit_point[0])}/{m.num_exit_points})")
    print("generated:", emitted)


if __name__ == "__main__":
    main()
