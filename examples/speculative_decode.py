"""T3 showcase: tree speculative decoding with hyper-token early exiting,
through the unified decode API (``TreeStrategy`` behind ``DecodeSession``).

    PYTHONPATH=src python examples/speculative_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.common import get_bundle
from repro.api import Engine, TreeStrategy
from repro.core.tree import TreeSpec


def main():
    b = get_bundle()
    m, params, sw = b.model, b.params, b.sw
    tree = TreeSpec(depth=2, branch=3)
    print(f"token tree: {tree.num_nodes} nodes, "
          f"{tree.path_nodes.shape[0]} hyper-token paths "
          f"(mapping complexity is LINEAR in paths — paper §6)")

    engine = Engine.create(m, params, sw, strategy=TreeStrategy(tree=tree))
    session = engine.new_session()
    prompt = jnp.arange(10)[None, :] % b.run.model.vocab_size
    res = session.prefill(prompt, max_seq=96)
    emitted = res.row_tokens(0)
    for step in range(10):
        res = session.step()
        new = res.row_tokens(0)
        emitted.extend(new)
        print(f"step {step}: accepted {int(res.accept_len[0])} draft "
              f"tokens + bonus -> {new} "
              f"(exit {int(res.exit_layer[0])}/{m.num_exit_points})")
    print("generated:", emitted)


if __name__ == "__main__":
    main()
