"""Serving driver (deliverable b): continuous-batching engine over the
unified decode API, with SpecEE as the fast path.

Trains the full SpecEE stack (draft + predictors + offline schedule) on a
smoke model, then serves a stream of batched requests through each decode
strategy — dense, AR SpecEE, and tree speculative decoding (tree-mode
serving emits up to depth+1 tokens per engine tick) — and reports
per-request exit/acceptance statistics.

    PYTHONPATH=src python examples/serve_specee.py --requests 6
    PYTHONPATH=src python examples/serve_specee.py --ci   # tiny CI smoke
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ci", action="store_true",
                    help="tiny smoke config: minimal training, 2 requests — "
                         "exercises the full API surface in seconds")
    args = ap.parse_args()
    if args.ci:
        args.requests, args.max_new = 2, 5

    print("training SpecEE bundle (target + draft + predictors)...")
    from benchmarks.common import get_bundle
    if args.ci:
        b = get_bundle(train_steps=2, draft_steps=8, pred_steps=20, layers=4)
    else:
        b = get_bundle()
    print(f"  draft top-k hit rate: {b.draft_metrics['topk_hit_rate']:.2f}")
    print(f"  predictor accuracy:   {b.predictor_metrics['accuracy']:.2f}")

    # prompts drawn from the training distribution (the predictors/draft were
    # trained on it — uniform-random tokens would never trigger exits)
    from benchmarks.common import token_batches
    rng = np.random.default_rng(0)
    pool = np.asarray(token_batches(b.run, 2, B=4, S=24, seed=77)[0])
    prompts = [pool[i % pool.shape[0], :int(rng.integers(6, 20))]
               for i in range(args.requests)]

    modes = ("specee", "dense") if args.ci else ("specee", "dense", "tree")
    results = {}
    for mode in modes:
        se = ServingEngine(b.model, b.params, b.sw, strategy=mode)
        reqs = [se.submit(p, max_new_tokens=args.max_new) for p in prompts]
        t0 = time.perf_counter()
        se.run_to_completion()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in reqs)
        results[mode] = (dt, toks)
        print(f"\n[{mode}] {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s)")
        for r in reqs[:3]:
            exits = [e for e in r.exit_points
                     if e < b.model.num_exit_points]
            line = (f"  req {r.uid}: {len(r.output)} tokens, "
                    f"{len(exits)}/{len(r.exit_points)} early exits, "
                    f"avg exit layer "
                    f"{np.mean(exits) if exits else float('nan'):.1f}")
            if mode == "tree":
                line += (f", {sum(r.accept_lens)} draft tokens accepted "
                         f"over {len(r.accept_lens)} ticks")
            print(line)
        ok = all(len(r.output) == args.max_new for r in reqs)
        assert ok, f"[{mode}] some requests did not complete their budget"
    sp = results["dense"][0] / results["specee"][0]
    print(f"\nSpecEE-vs-dense wall clock through the serving engine: {sp:.2f}x"
          f"\n(NOTE: this demo measures the CONTINUOUS-BATCHING wrapper on "
          f"CPU, whose per-tick host overhead dwarfs the tiny smoke model; "
          f"the engine-level speedup measurement is benchmarks/bench_speedup "
          f"— 1.7–1.9x at smoke scale. The numbers to read here are the "
          f"early-exit counts and layers above.)")


if __name__ == "__main__":
    main()
