"""End-to-end driver (deliverable b): train a ~smoke model for a few hundred
steps with the full production loop — data pipeline, AdamW + schedule,
checkpointing, restart, straggler monitor.

    PYTHONPATH=src python examples/train_small.py --arch minicpm-2b \
        --steps 200 --ckpt /tmp/repro_ckpt

Kill it mid-run and rerun the same command: it restores the latest
checkpoint and the loss curve continues exactly where it stopped.
"""
import argparse

import jax

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")  # WSD schedule showcase
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    import dataclasses
    run = get_config(args.arch).smoke()
    # schedule horizon = the requested step count (smoke default is 2)
    run = dataclasses.replace(
        run, train=dataclasses.replace(run.train, steps=args.steps))
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    loop = TrainLoop(model, run, params, ckpt_dir=args.ckpt)
    loop.guard.install()  # SIGTERM -> final checkpoint
    if loop.try_restore():
        print(f"resumed from step {loop.step} "
              f"(data_step={loop.pipeline.step})")
    while loop.step < args.steps:
        stats = loop.run_steps(10)
        print(f"step {loop.step:5d} loss={stats['loss']:.4f} "
              f"lr={stats['lr']:.2e} gnorm={stats['grad_norm']:.2f} "
              f"({stats['step_time']*1000:.0f} ms/step, schedule="
              f"{run.train.schedule})")
    if args.ckpt:
        loop.save()
        loop.ckpt.wait()
        print("final checkpoint written")


if __name__ == "__main__":
    main()
