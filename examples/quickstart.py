"""Quickstart: build a model, run SpecEE decoding, inspect exits.

    PYTHONPATH=src python examples/quickstart.py [--arch llama2-7b]

Uses the smoke-scale config so it runs on a laptop CPU in seconds; every
line is the same public API a full-scale deployment uses.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    # 1. config + model (smoke-scale: same family, laptop-sized)
    run = get_config(args.arch).smoke()
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{args.arch} (smoke): {model.num_exit_points} exit points, "
          f"segments={model.segments}")

    # 2. SpecEE weights: draft (DLM) + per-exit-point predictors + schedule
    sw = eng.init_specee(model, jax.random.PRNGKey(1))

    # 3. prefill a prompt, then decode with speculative early exiting
    prompt = jnp.arange(12)[None, :] % run.model.vocab_size
    first, state = eng.init_decode_state(model, params, sw,
                                         {"tokens": prompt},
                                         max_seq=64)
    tokens = [int(first[0])]
    for _ in range(args.new_tokens):
        tok, state, info = eng.ar_decode_step(model, params, sw, state)
        tokens.append(int(tok[0]))
        print(f"  token={int(tok[0]):6d} exit_point="
              f"{int(info.exit_point[0])}/{model.num_exit_points} "
              f"exited={bool(info.exited[0])} "
              f"units_run={int(info.units_run)}")
    print("generated:", tokens)


if __name__ == "__main__":
    main()
