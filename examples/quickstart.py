"""Quickstart: build a model, decode through the unified API, inspect exits.

    PYTHONPATH=src python examples/quickstart.py [--arch llama2-7b]
                                                 [--strategy specee|dense|tree]

Uses the smoke-scale config so it runs on a laptop CPU in seconds; every
line is the same public API a full-scale deployment uses:

    engine  = Engine.create(model, params, sw, strategy="specee")
    session = engine.new_session()
    result  = session.prefill(prompts, max_new_tokens=N)   # StepResult
    result  = session.step()                               # StepResult

``StepResult`` is canonical across strategies — dense full-depth, AR SpecEE
(1 token/tick), and tree speculative decoding (up to depth+1 tokens/tick)
all emit (tokens, counts, done, exit_layer, accept_len, ...), so this loop
is strategy-agnostic.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import Engine
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--strategy", default="specee",
                    choices=["specee", "dense", "tree"])
    args = ap.parse_args()

    # 1. config + model (smoke-scale: same family, laptop-sized)
    run = get_config(args.arch).smoke()
    model = build_model(run)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{args.arch} (smoke): {model.num_exit_points} exit points, "
          f"segments={model.segments}")

    # 2. SpecEE weights: draft (DLM) + per-exit-point predictors + schedule
    sw = eng.init_specee(model, jax.random.PRNGKey(1))

    # 3. one engine surface for every decode strategy
    engine = Engine.create(model, params, sw, strategy=args.strategy)
    session = engine.new_session()

    prompt = jnp.arange(12)[None, :] % run.model.vocab_size
    res = session.prefill(prompt, max_new_tokens=args.new_tokens + 1)
    tokens = res.row_tokens(0)
    while not session.all_done():
        res = session.step()
        tokens.extend(res.row_tokens(0))
        print(f"  emitted={res.row_tokens(0)} "
              f"exit_layer={int(res.exit_layer[0])}/{model.num_exit_points} "
              f"exited={bool(res.exited[0])} "
              f"accept_len={int(res.accept_len[0])} "
              f"units_run={int(res.units_run)}")
    print("generated:", tokens)


if __name__ == "__main__":
    main()
