"""T3 tests: static tree structure, hyper-token merged mapping, tree decode
equivalence + oracle acceptance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import engine as eng
from repro.core import features as feat_lib
from repro.core.tree import TreeSpec
from repro.models.model import build_model


def test_tree_structure():
    t = TreeSpec(depth=2, branch=3)
    assert t.num_nodes == 13
    assert t.level_sizes == [1, 3, 9]
    assert t.path_nodes.shape == (9, 3)
    # parents
    assert t.parents[0] == -1
    assert all(t.parents[i] == 0 for i in (1, 2, 3))
    assert t.parents[4] == 1 and t.parents[12] == 3
    # every path starts at root and respects parent links
    for path in t.path_nodes:
        assert path[0] == 0
        for a, b in zip(path[:-1], path[1:]):
            assert t.parents[b] == a
    # ancestor mask: diagonal true; child sees parent; parent not child
    am = t.ancestor_mask
    assert am.diagonal().all()
    assert am[4, 1] and not am[1, 4]
    # children table inverse of parents
    for n in range(t.num_nodes):
        p = t.parents[n]
        if p >= 0:
            assert n in t.children[p]


def test_linear_vs_exponential_mapping_complexity():
    """The hyper-token mapping is one predictor eval per PATH (linear),
    versus per-node independent mapping (b^depth · depth node evals)."""
    for depth in (1, 2, 3):
        t = TreeSpec(depth=depth, branch=3)
        assert t.path_nodes.shape[0] == 3 ** depth
        # mapping evals per exit point = P (merged) vs sum over levels (naive)
        merged = t.path_nodes.shape[0]
        assert merged == 3 ** depth  # linear in #paths, one per hyper-token


def test_merge_path_features_is_cannikin_min():
    B, N, k = 2, 5, 4
    feats = jax.random.normal(jax.random.PRNGKey(0), (B, N, 3 * k))
    probs = jax.random.uniform(jax.random.PRNGKey(1), (B, N, k))
    paths = jnp.array([[0, 1, 3], [0, 2, -1]], jnp.int32)
    lens = jnp.array([3, 2])
    pf, pp = feat_lib.merge_path_features(feats, probs, paths, lens)
    np.testing.assert_allclose(pf[:, 0], jnp.min(feats[:, [0, 1, 3]], axis=1))
    np.testing.assert_allclose(pf[:, 1], jnp.min(feats[:, [0, 2]], axis=1))
    np.testing.assert_allclose(pp[:, 1], jnp.min(probs[:, [0, 2]], axis=1))


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    tree = TreeSpec(depth=2, branch=3)
    return run, m, params, sw, tree


def _dense_ref(m, params, tokens, steps, max_seq):
    logits, cache, _ = m.prefill(params, {"tokens": tokens}, max_seq=max_seq)
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    tok = out[0]
    for _ in range(steps):
        logits, cache = m.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, 1)


def test_tree_no_exit_matches_dense(setup):
    run, m, params, sw, tree = setup
    B, T = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                run.model.vocab_size)
    ref = _dense_ref(m, params, tokens, 10, 48 + tree.num_nodes)
    first, st = eng.init_tree_decode_state(m, params, sw, {"tokens": tokens},
                                           48, tree)
    emitted = [[int(first[b])] for b in range(B)]
    for _ in range(7):
        out, n, st, info = eng.tree_decode_step(m, params, sw, st, tree,
                                                threshold=1.5)
        for b in range(B):
            emitted[b].extend(int(x) for x in out[b, :int(n[b])])
    for b in range(B):
        got = emitted[b][:ref.shape[1]]
        assert got == [int(x) for x in ref[b]][:len(got)], f"row {b}"


def test_tree_oracle_acceptance(setup):
    """Tree whose first chain matches the dense continuation accepts depth
    tokens + bonus each step, all equal to the dense reference (also proves
    the accepted-KV commit is correct across steps)."""
    run, m, params, sw, tree = setup
    B, T = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                run.model.vocab_size)
    ref = np.asarray(_dense_ref(m, params, tokens, 12, 64 + tree.num_nodes))
    first, st = eng.init_tree_decode_state(m, params, sw, {"tokens": tokens},
                                           64, tree)
    ptr = [1, 1]
    for step in range(4):
        node_toks = np.random.default_rng(step).integers(
            0, run.model.vocab_size, (B, tree.num_nodes)).astype(np.int32)
        for b in range(B):
            node_toks[b, 1] = ref[b, ptr[b]]
            node_toks[b, 4] = ref[b, ptr[b] + 1]
        out, n, st, info = eng.tree_decode_step(
            m, params, sw, st, tree, threshold=1.5,
            node_tokens_override=jnp.asarray(node_toks))
        assert [int(x) for x in info.accepted_len] == [2, 2]
        for b in range(B):
            got = [int(x) for x in out[b, :int(n[b])]]
            exp = [int(x) for x in ref[b, ptr[b]:ptr[b] + int(n[b])]]
            assert got == exp, f"step {step} row {b}: {got} vs {exp}"
            ptr[b] += int(n[b])


def test_tree_requires_attention_stack():
    run = get_config("mamba2-130m").smoke()
    m = build_model(run)
    assert not m.supports_tree()
    run2 = get_config("llama2-7b").smoke()
    assert build_model(run2).supports_tree()
