"""SpecEE core tests: T1 features/predictor, verification invariants,
scheduler (T2), predictor training, oracle exits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SpecEEConfig
from repro.configs import get_config
from repro.core import draft as draft_lib
from repro.core import engine as eng
from repro.core import features as feat_lib
from repro.core import predictor as pred_lib
from repro.core import scheduler as sched_lib
from repro.models.common import lm_head_weight
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


# ---------------- T1: features + predictor ----------------
def test_feature_dims():
    spec = SpecEEConfig()
    assert spec.num_speculative == 4          # paper §4.3.2
    assert spec.feature_dim() == 12           # 4 tokens × 3 features
    assert spec.predictor_hidden == 512       # paper Fig. 8 DSE optimum
    assert spec.predictor_layers == 2


def test_predictor_memory_matches_paper():
    """Paper §7.4.2: all predictors of Llama2-7B ≈ 416 KB ((12·512+512·1)
    weights, fp16, no biases). Ours stores fp32 + biases → ~918 KB; the
    claim we verify is the ORDER: predictors ≪ 1 MB ≪ the DLM."""
    spec = SpecEEConfig()
    b = pred_lib.predictor_param_bytes(spec, 32)
    weights_only_fp16 = (12 * 512 + 512 * 1) * 32 * 2
    assert weights_only_fp16 == 425_984          # the paper's 416 KiB
    assert b < 1_000_000, f"{b} bytes"


def test_features_match_full_head(setup):
    run, m, params, sw = setup
    B, k = 3, 4
    hn = jax.random.normal(jax.random.PRNGKey(2), (B, run.model.d_model))
    lm_w = lm_head_weight(params)
    ids = jax.random.randint(jax.random.PRNGKey(3), (B, k), 0,
                             run.model.vocab_size)
    prev = jnp.full((B, k), 0.25)
    feats, probs = feat_lib.extract_features(hn, lm_w, ids, prev)
    # speculative logits must equal the matching columns of the full head
    full = hn.astype(jnp.float32) @ lm_w.astype(jnp.float32)
    expect = jnp.take_along_axis(full, ids, axis=1)
    np.testing.assert_allclose(feats[:, :k], expect, rtol=2e-5, atol=1e-5)
    # probs are a softmax over the k logits (local, not global)
    np.testing.assert_allclose(jnp.sum(probs, -1), 1.0, rtol=1e-5)
    # variation = probs - prev
    np.testing.assert_allclose(feats[:, 2 * k:], probs - prev, atol=1e-6)


def test_predictor_stacked_indexing():
    spec = SpecEEConfig()
    bank = pred_lib.init_predictors(spec, 5, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (7, spec.feature_dim()))
    for e in [0, 3, 4]:
        p = pred_lib.predictor_at(bank, jnp.int32(e))
        out = pred_lib.apply_predictor(p, x)
        assert out.shape == (7,)
        assert ((out >= 0) & (out <= 1)).all()


# ---------------- verification + engine invariants ----------------
def test_no_exit_equivalence(setup):
    """threshold > 1 ⇒ SpecEE output bit-identical to dense greedy decode."""
    run, m, params, sw = setup
    B, T, G = 2, 8, 5
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0,
                                run.model.vocab_size)
    logits, cache, _ = m.prefill(params, {"tokens": tokens}, max_seq=T + G + 1)
    ref = [jnp.argmax(logits, -1).astype(jnp.int32)]
    tok = ref[0]
    for _ in range(G):
        logits, cache = m.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ref.append(tok)
    first, st = eng.init_decode_state(m, params, sw, {"tokens": tokens},
                                      T + G + 1)
    got = [first]
    for _ in range(G):
        tok2, st, info = eng.ar_decode_step(m, params, sw, st, threshold=1.5)
        assert not bool(info.exited.any())
        got.append(tok2)
    for a, b in zip(ref, got):
        assert bool((a == b).all())


def test_oracle_exit_verified(setup):
    """With an oracle speculative set (contains the layer-truth), forcing the
    predictor (threshold<0) must exit at the FIRST exit point whose global
    argmax lies in the set, and emit exactly that argmax."""
    run, m, params, sw = setup
    B, T = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0,
                                run.model.vocab_size)
    first, st = eng.init_decode_state(m, params, sw, {"tokens": tokens}, T + 4)

    # compute layer-wise global argmax at the first decode position by hand
    h = m.embed(params, first[:, None])[:, 0, :]
    pos = st.cache["len"]
    argmaxes = []
    seg_cache = st.cache["segments"][0]
    for u in range(m.segments[0][1]):
        h, seg_cache = m.run_unit(params, 0, jnp.int32(u), h, seg_cache, pos)
        glog = m.logits(params, h)
        argmaxes.append(jnp.argmax(glog, -1).astype(jnp.int32))
    # oracle set = argmax after unit 1 (plus junk)
    k = run.specee.num_speculative
    oracle = jnp.stack([argmaxes[1]] * k, axis=1)
    tok, st2, info = eng.ar_decode_step(m, params, sw, st, threshold=-0.1,
                                        spec_ids_override=oracle)
    assert bool(info.exited.all())
    assert [int(x) for x in info.exit_point] == [1, 1]
    assert bool((tok == argmaxes[1]).all())


def test_exit_freezes_recurrent_state():
    """For SSM archs, rows that exit keep their SSM state stale while live
    rows advance (live-mask semantics)."""
    run = get_config("mamba2-130m").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    cache = m.empty_cache(B, 8)
    h = jax.random.normal(jax.random.PRNGKey(1), (B, run.model.d_model))
    seg = cache["segments"][0]
    live = jnp.array([True, False])
    _, seg2 = m.run_unit(params, 0, jnp.int32(0), h, seg,
                         cache["len"], live_mask=live)
    s_old = seg["u0"]["state"][0]
    s_new = seg2["u0"]["state"][0]
    assert not np.allclose(s_new[0], s_old[0])      # live row advanced
    np.testing.assert_allclose(s_new[1], s_old[1])  # exited row stale


# ---------------- T2: scheduler ----------------
def test_scheduler_active_mask_union():
    spec = SpecEEConfig(online_window=3, online_radius=2, offline_top_frac=0.25)
    E = 16
    st = sched_lib.init_state(2, spec)
    offline = jnp.zeros((E,), bool).at[jnp.array([0, 5])].set(True)
    # empty queue: only offline
    am = sched_lib.active_mask(st, offline, spec, E)
    np.testing.assert_array_equal(am[0], offline)
    # push exit at 10 for row 0, 3 for row 1
    st = sched_lib.update(st, jnp.array([10, 3]))
    am = sched_lib.active_mask(st, offline, spec, E)
    for e in range(E):
        exp0 = bool(offline[e]) or abs(e - 10) <= 2
        exp1 = bool(offline[e]) or abs(e - 3) <= 2
        assert bool(am[0, e]) == exp0
        assert bool(am[1, e]) == exp1


def test_scheduler_circular_queue():
    spec = SpecEEConfig(online_window=2)
    st = sched_lib.init_state(1, spec)
    st = sched_lib.update(st, jnp.array([1]))
    st = sched_lib.update(st, jnp.array([2]))
    st = sched_lib.update(st, jnp.array([3]))  # evicts 1
    q = sorted(int(x) for x in st["queue"][0])
    assert q == [2, 3]


def test_offline_mask_from_counts():
    spec = SpecEEConfig(offline_top_frac=0.25)
    counts = jnp.array([5, 100, 2, 50, 1, 1, 1, 1], jnp.float32)
    mask = sched_lib.offline_mask_from_counts(counts, spec)
    assert int(mask.sum()) == 2
    assert bool(mask[1]) and bool(mask[3])


def test_schedule_reduces_predictor_evals(setup):
    """T2 claim: scheduling activates far fewer predictors than all-layers."""
    run, m, params, sw = setup
    spec = dataclasses.replace(run.specee, offline_top_frac=0.25)
    E = 32
    st = sched_lib.init_state(4, spec)
    offline = jnp.zeros((E,), bool).at[:8].set(True)
    st = sched_lib.update(st, jnp.array([10, 10, 11, 9]))
    n = float(sched_lib.expected_active_count(st, offline, spec, E))
    assert n < 0.5 * E  # ~13 of 32


# ---------------- draft ----------------
def test_draft_param_overhead(setup):
    """DLM ≈ one decoder layer (+fusion): a few % of the target model."""
    run, m, params, sw = setup
    n_target = sum(x.size for x in jax.tree_util.tree_leaves(params))
    n_draft = sum(x.size for x in jax.tree_util.tree_leaves(sw.draft))
    assert n_draft < 0.6 * n_target  # smoke models are tiny; full ≈ 3%
    full = get_config("llama2-7b")
    n_full_draft = draft_lib.draft_param_count(full.model)
    assert n_full_draft < 0.05 * full.model.param_count()


def test_draft_topk_shapes(setup):
    run, m, params, sw = setup
    B = 2
    h = jax.random.normal(jax.random.PRNGKey(6), (B, run.model.d_model))
    ids, logits = draft_lib.propose_topk(m, params, h, 4)
    assert ids.shape == (B, 4) and logits.shape == (B, 4)
    # top-k really is top-k of the head
    full = m.logits(params, h)
    expect = jax.lax.top_k(full, 4)[1]
    np.testing.assert_array_equal(ids, expect)


# ---------------- predictor training pipeline ----------------
def test_predictor_training_learns(setup):
    run, m, params, sw = setup
    from repro.core import predictor_training as pt
    batches = [jax.random.randint(jax.random.PRNGKey(i), (4, 24), 0,
                                  run.model.vocab_size) for i in range(2)]
    data = pt.collect_dataset(m, params, sw.draft, batches)
    E = m.num_exit_points
    assert data.features.shape[0] == E
    assert data.features.shape[2] == run.specee.feature_dim()
    pred, metrics = pt.train_predictors(run.specee, data,
                                        jax.random.PRNGKey(3), steps=120)
    base = max(metrics["positive_rate"], 1 - metrics["positive_rate"])
    assert metrics["accuracy"] >= base - 0.02  # at least the trivial rate
