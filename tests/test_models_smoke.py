"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs; plus the decode
equivalence invariant that the whole serving stack rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import DataPipeline
from repro.models.model import build_model, segments_of
from repro.models import frontends


def make_batch(run, B=2, S=16, key=0):
    cfg = run.model
    rng = np.random.default_rng(key)
    if cfg.frontend == "audio_frames":
        return {
            "frames": jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                                  jnp.float32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
            "mask": jnp.asarray(rng.random((B, S)) < 0.4)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.frontend == "vision_patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_tokens,
                                 frontends.FRONTEND_DIM)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    run = get_config(arch).smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(run)
    loss, aux = m.train_loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"

    logits, cache, extras = m.prefill(params, batch, max_seq=24)
    if run.model.is_decoder():
        assert logits.shape == (2, run.model.vocab_size)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        lg2, cache2 = m.decode_step(params, tok, cache)
        assert lg2.shape == (2, run.model.vocab_size)
        assert jnp.isfinite(lg2).all()
        assert int(cache2["len"][0]) == int(cache["len"][0]) + 1
    else:
        assert logits.shape[-1] == run.model.vocab_size
        assert jnp.isfinite(logits).all()

    # one optimizer step decreases nothing catastrophic (finite grads)
    from repro.train.loop import make_train_step
    from repro.optim import adamw_init
    import dataclasses
    step = make_train_step(m, dataclasses.replace(run.train, steps=2))
    opt = adamw_init(params)
    p2, opt2, stats = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(stats["loss"])
    assert jnp.isfinite(stats["grad_norm"])


@pytest.mark.parametrize("arch", ["llama2-7b", "dbrx-132b", "mamba2-130m",
                                  "recurrentgemma-9b", "starcoder2-15b",
                                  "qwen3-moe-235b-a22b"])
def test_decode_matches_full_forward(arch):
    """prefill+decode_step must reproduce teacher-forced full-forward logits."""
    run = get_config(arch).smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    B, S, T = 2, 12, 6
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                run.model.vocab_size)
    h = m.embed(params, tokens)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    hf, _, _ = m.forward_hidden(params, h, pos)
    full_logits = m.logits(params, hf)
    logits, cache, _ = m.prefill(params, {"tokens": tokens[:, :T]},
                                 max_seq=S + 2)
    np.testing.assert_allclose(logits, full_logits[:, T - 1], atol=2e-4)
    for t in range(T, S):
        logits, cache = m.decode_step(params, tokens[:, t], cache)
        np.testing.assert_allclose(logits, full_logits[:, t], atol=2e-4,
                                   err_msg=f"{arch} step {t}")


def test_segments_decomposition():
    assert segments_of(["attention"] * 7) == [(("attention",), 7)]
    assert segments_of(["ssd"] * 3) == [(("ssd",), 3)]
    pat = ["rglru", "rglru", "local_attention"] * 12 + ["rglru", "rglru"]
    assert segments_of(pat) == [(("rglru", "rglru", "local_attention"), 12),
                                (("rglru",), 2)]
    # recompose invariance
    segs = segments_of(pat)
    flat = [k for unit, reps in segs for _ in range(reps) for k in unit]
    assert flat == pat


def test_chunked_attention_equals_naive():
    from repro.models import attention as attn
    run = get_config("starcoder2-15b").smoke()
    cfg = run.model
    B, S = 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0),
                          (B, S, cfg.num_heads, cfg.resolved_head_dim()))
    k = jax.random.normal(jax.random.PRNGKey(1),
                          (B, S, cfg.num_kv_heads, cfg.resolved_head_dim()))
    v = jax.random.normal(jax.random.PRNGKey(2),
                          (B, S, cfg.num_kv_heads, cfg.resolved_head_dim()))
    a = attn.attend_full(cfg, q, k, v)
    b = attn.attend_full_chunked(cfg, q, k, v, chunk=16)
    np.testing.assert_allclose(a, b, atol=1e-5)
    # windowed
    a = attn.attend_full(cfg, q, k, v, window=8)
    b = attn.attend_full_chunked(cfg, q, k, v, window=8, chunk=16)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_chunked_ce_matches_direct():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 40
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, run.model.d_model))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                             run.model.vocab_size)
    direct = m._ce_loss(params, h, tgt, chunk=S)  # single chunk == direct
    # force the chunked path by tiny chunk
    chunked = m._ce_loss.__wrapped__(m, params, h, tgt, 16) \
        if hasattr(m._ce_loss, "__wrapped__") else m._ce_loss(params, h, tgt,
                                                              chunk=16)
    np.testing.assert_allclose(direct, chunked, rtol=1e-5)


def test_int8_kv_cache_close_to_fp():
    """§Perf beyond-paper lever: int8 KV cache keeps greedy decode faithful."""
    from repro.models.model import ModelFlags
    run = get_config("llama2-7b").smoke()
    m0 = build_model(run)
    m8 = build_model(run, ModelFlags(kv_quant=True))
    params = m0.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                run.model.vocab_size)
    l0, c0, _ = m0.prefill(params, {"tokens": tokens}, max_seq=16)
    l8, c8, _ = m8.prefill(params, {"tokens": tokens}, max_seq=16)
    tok = jnp.argmax(l0, -1).astype(jnp.int32)
    for _ in range(4):
        l0, c0 = m0.decode_step(params, tok, c0)
        l8, c8 = m8.decode_step(params, tok, c8)
        assert float(jnp.max(jnp.abs(l0 - l8))) < 0.2
        tok = jnp.argmax(l0, -1).astype(jnp.int32)
