import os

# Tests must see exactly ONE device (the dry-run sets its own 512-device
# flag in its own process). Also keep XLA from grabbing every core.
os.environ.setdefault("XLA_FLAGS", "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
