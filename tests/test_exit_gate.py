"""Fused exit-gate pipeline: kernel-vs-reference parity and engine
equivalence (PR: one Pallas chain for spec-head → predictor → streaming
argmax-verify in the decode hot loop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SpecEEConfig
from repro.configs import get_config
from repro.core import engine as eng
from repro.core import predictor as pred_lib
from repro.core.tree import TreeSpec
from repro.kernels.exit_gate import ops as gate_ops
from repro.kernels.exit_gate.ref import exit_gate_ref, verify_argmax_ref
from repro.models.model import ModelFlags, build_model


def _inputs(B, D, V, k, dtype=jnp.float32, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    hn = jax.random.normal(keys[0], (B, D)).astype(dtype)
    W = (jax.random.normal(keys[1], (D, V)) * 0.05).astype(dtype)
    ids = jax.random.randint(keys[2], (B, k), 0, V)
    prev = jax.nn.softmax(jax.random.normal(keys[3], (B, k)))
    return hn, W, ids, prev


# ---------------- gate kernel vs oracle ----------------
# shapes cover: 128-aligned, non-128-aligned D AND V, k≠4, and the tree
# path's B·N row layout (B=2 × N=13 nodes)
GATE_SHAPES = [(4, 256, 512, 4), (3, 384, 1001, 4), (2, 320, 777, 5),
               (26, 128, 512, 4), (1, 200, 65, 3)]


@pytest.mark.parametrize("B,D,V,k", GATE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_exit_gate_matches_ref(B, D, V, k, dtype, impl):
    spec = SpecEEConfig(num_speculative=k, predictor_hidden=64)
    bank = pred_lib.init_predictors(spec, 6, jax.random.PRNGKey(7))
    hn, W, ids, prev = _inputs(B, D, V, k, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    for ep in (0, 5):
        p, probs, logits = gate_ops.exit_gate(hn, W, ids, prev, bank,
                                              jnp.int32(ep), impl=impl)
        pp = jax.tree_util.tree_map(lambda x: x[ep], bank)
        p_r, probs_r, logits_r = exit_gate_ref(hn, W, ids, prev, pp)
        np.testing.assert_allclose(p, p_r, atol=tol, rtol=tol)
        np.testing.assert_allclose(probs, probs_r, atol=tol, rtol=tol)
        np.testing.assert_allclose(logits, logits_r, atol=10 * tol,
                                   rtol=tol)


def test_exit_gate_non_2layer_bank_falls_back():
    """DSE banks (1- or 3-layer predictors) must still work via "kernel"."""
    for layers in (1, 3):
        spec = SpecEEConfig(num_speculative=4, predictor_hidden=32,
                            predictor_layers=layers)
        bank = pred_lib.init_predictors(spec, 3, jax.random.PRNGKey(1))
        hn, W, ids, prev = _inputs(2, 128, 256, 4)
        p, _, _ = gate_ops.exit_gate(hn, W, ids, prev, bank, jnp.int32(1),
                                     impl="kernel")
        pp = jax.tree_util.tree_map(lambda x: x[1], bank)
        p_r, _, _ = exit_gate_ref(hn, W, ids, prev, pp)
        np.testing.assert_allclose(p, p_r, atol=1e-6)


# ---------------- streaming argmax-verify vs oracle ----------------
VERIFY_SHAPES = [(4, 256, 512), (3, 384, 1001), (2, 320, 777),
                 (26, 128, 512), (1, 200, 1300)]


@pytest.mark.parametrize("B,D,V", VERIFY_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_verify_argmax_matches_ref(B, D, V, dtype, impl):
    hn, W, _, _ = _inputs(B, D, V, 4, dtype, seed=3)
    tok, mx = gate_ops.verify_argmax(hn, W, impl=impl, block_v=256)
    tok_r, mx_r = verify_argmax_ref(hn, W)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_r))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(mx, mx_r, atol=tol, rtol=tol)


def test_verify_argmax_tie_breaks_to_first():
    """Duplicate LM-head columns ⇒ duplicated max logit; the streaming
    kernel must resolve to the lowest index like jnp.argmax."""
    hn = jnp.ones((2, 128))
    W = jax.random.normal(jax.random.PRNGKey(0), (128, 300)) * 0.1
    peak = jnp.max(hn @ W, axis=-1, keepdims=False)
    # plant the same winning column at 17 and 210 (different vocab tiles)
    col = W[:, jnp.argmax((hn @ W)[0])]
    W = W.at[:, 17].set(col).at[:, 210].set(col)
    for impl in ("kernel", "xla"):
        tok, _ = gate_ops.verify_argmax(hn, W, impl=impl, block_v=128)
        ref_tok = jnp.argmax(hn @ W, axis=-1)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(ref_tok))


# ---------------- streaming top-k verify vs oracle ----------------
TOPK_SHAPES = [(4, 256, 512, 4), (3, 384, 1001, 4), (2, 320, 777, 5),
               (1, 200, 65, 3), (26, 128, 512, 4)]


@pytest.mark.parametrize("B,D,V,k", TOPK_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_verify_topk_matches_ref(B, D, V, k, dtype, impl):
    """The streaming top-k (draft proposal path) id-matches ``jax.lax.top_k``
    on the materialized logits, including order."""
    from repro.kernels.exit_gate.ref import verify_topk_ref
    hn, W, _, _ = _inputs(B, D, V, k, dtype, seed=11)
    ids, vals = gate_ops.verify_topk(hn, W, k, impl=impl, block_v=256)
    ids_r, vals_r = verify_topk_ref(hn, W, k)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_r))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(vals, vals_r, atol=tol, rtol=tol)


def test_verify_topk_tie_breaks_to_first():
    """Duplicate LM-head columns across vocab tiles: the running top-k must
    keep the lowest ids in jnp.top_k's order."""
    from repro.kernels.exit_gate.ref import verify_topk_ref
    hn = jnp.ones((2, 128))
    W = jax.random.normal(jax.random.PRNGKey(2), (128, 300)) * 0.1
    col = W[:, jnp.argmax((hn @ W)[0])]
    W = W.at[:, 17].set(col).at[:, 210].set(col)
    ids_r, _ = verify_topk_ref(hn, W, 4)
    for impl in ("kernel", "xla"):
        ids, _ = gate_ops.verify_topk(hn, W, 4, impl=impl, block_v=128)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_r))


def test_propose_topk_streams_through_gate():
    """propose_topk keeps its historical numerics ("ref" impl) and id-matches
    the streaming impls under the fused flag."""
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    from repro.core import draft as draft_lib
    h = jax.random.normal(jax.random.PRNGKey(6), (3, run.model.d_model))
    ids, vals = draft_lib.propose_topk(m, params, h, 4)
    full = m.logits(params, h)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(jax.lax.top_k(full, 4)[1]))
    m_fused = build_model(run, ModelFlags(exit_gate_kernel=True,
                                          exit_gate_impl="kernel"))
    ids_f, _ = draft_lib.propose_topk(m_fused, params, h, 4)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_f))


# ---------------- engine equivalence ----------------
@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _ar_run(m, params, sw, tokens, thresh, steps):
    T = tokens.shape[1]
    first, st = eng.init_decode_state(m, params, sw, {"tokens": tokens},
                                      T + steps + 1)
    out, exits, exited = [first], [], []
    for _ in range(steps):
        tok, st, info = eng.ar_decode_step(m, params, sw, st,
                                           threshold=thresh)
        out.append(tok)
        exits.append(info.exit_point)
        exited.append(info.exited)
    return (np.asarray(jnp.stack(out, 1)), np.asarray(jnp.stack(exits, 1)),
            np.asarray(jnp.stack(exited, 1)))


@pytest.mark.parametrize("thresh", [1.5, 0.4, -0.1])
def test_ar_fused_bitwise_matches_reference(setup, thresh):
    """Emitted tokens AND exit decisions of the fused gate are identical to
    the reference four-op path (threshold>1 also re-proves the dense-greedy
    invariant under the fused flag)."""
    run, m, params, sw = setup
    m_fused = build_model(run, ModelFlags(exit_gate_kernel=True,
                                          exit_gate_impl="xla"))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                                run.model.vocab_size)
    t_ref, e_ref, x_ref = _ar_run(m, params, sw, tokens, thresh, 5)
    t_fus, e_fus, x_fus = _ar_run(m_fused, params, sw, tokens, thresh, 5)
    np.testing.assert_array_equal(t_ref, t_fus)
    np.testing.assert_array_equal(e_ref, e_fus)
    np.testing.assert_array_equal(x_ref, x_fus)
    if thresh > 1.0:
        assert not x_ref.any()


def test_ar_fused_kernel_chain_in_engine(setup):
    """The full Pallas chain (interpret mode on CPU) inside the decode
    while_loop emits the same tokens as the reference."""
    run, m, params, sw = setup
    m_ker = build_model(run, ModelFlags(exit_gate_kernel=True,
                                        exit_gate_impl="kernel"))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                                run.model.vocab_size)
    t_ref, e_ref, _ = _ar_run(m, params, sw, tokens, 0.4, 2)
    t_ker, e_ker, _ = _ar_run(m_ker, params, sw, tokens, 0.4, 2)
    np.testing.assert_array_equal(t_ref, t_ker)
    np.testing.assert_array_equal(e_ref, e_ker)


def _tree_run(m, params, sw, tokens, tree, thresh, steps):
    first, st = eng.init_tree_decode_state(m, params, sw,
                                           {"tokens": tokens}, 48, tree)
    outs = []
    for _ in range(steps):
        out, n, st, info = eng.tree_decode_step(m, params, sw, st, tree,
                                                threshold=thresh)
        outs.append((np.asarray(out), np.asarray(n),
                     np.asarray(info.exit_point)))
    return outs


@pytest.mark.parametrize("thresh", [1.5, 0.3])
def test_tree_fused_matches_reference(setup, thresh):
    run, m, params, sw = setup
    m_fused = build_model(run, ModelFlags(exit_gate_kernel=True,
                                          exit_gate_impl="xla"))
    tree = TreeSpec(depth=2, branch=3)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                                run.model.vocab_size)
    ref = _tree_run(m, params, sw, tokens, tree, thresh, 3)
    fus = _tree_run(m_fused, params, sw, tokens, tree, thresh, 3)
    for (o1, n1, e1), (o2, n2, e2) in zip(ref, fus):
        np.testing.assert_array_equal(o1, o2)
        np.testing.assert_array_equal(n1, n2)
        np.testing.assert_array_equal(e1, e2)


def test_tree_spec_head_kernel_reachable(setup, monkeypatch):
    """Regression: tree_decode_step used to drop ``use_kernel``, so the
    spec_head Pallas kernel was silently unreachable in tree mode."""
    import repro.kernels.spec_head.ops as sh_ops
    run, m, params, sw = setup
    calls = {"n": 0}
    orig = sh_ops.spec_head

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(sh_ops, "spec_head", counting)
    m_sh = build_model(run, ModelFlags(spec_head_kernel=True))
    tree = TreeSpec(depth=2, branch=3)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0,
                                run.model.vocab_size)
    _tree_run(m_sh, params, sw, tokens, tree, 0.3, 1)
    assert calls["n"] > 0


def test_banked_predictor_kernel_matches_ref():
    """apply_predictor_banked(use_kernel=True) routes the bank dynamic_index
    through the fused-MLP wrapper with identical numerics, including 3-dim
    (B, P, F) tree-path features."""
    spec = SpecEEConfig(predictor_hidden=64)
    bank = pred_lib.init_predictors(spec, 5, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, spec.feature_dim()))
    for ep in (0, 4):
        got = pred_lib.apply_predictor_banked(bank, jnp.int32(ep), x,
                                              use_kernel=True)
        ref = pred_lib.apply_predictor(
            pred_lib.predictor_at(bank, jnp.int32(ep)), x)
        assert got.shape == ref.shape == (2, 9)
        np.testing.assert_allclose(got, ref, atol=1e-6)
