"""Device-resident multi-tick decode (megatick) tests: token parity of
``step(num_ticks=K)`` vs K single steps across all three strategies × both
cache layouts (mid-megatick EOS and budget exhaustion included), buffer
donation safety, the widened StepResult contract, and the async serving
pipeline's end-to-end parity."""
import jax
import numpy as np
import pytest

from repro.api import (DenseStrategy, Engine, SpecEEStrategy, TreeStrategy)
from repro.configs import get_config
from repro.core import engine as eng
from repro.core.tree import TreeSpec
from repro.models.model import build_model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _prompts(run, B=2, T=8, seed=4):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, T), 0,
                              run.model.vocab_size)


def _strategy(name):
    return {"dense": DenseStrategy(),
            "specee": SpecEEStrategy(),
            "tree": TreeStrategy(tree=TreeSpec(depth=2, branch=3))}[name]


def _drain_single(session, first):
    toks = [first.row_tokens(b) for b in range(first.batch)]
    stats = [[] for _ in range(first.batch)]
    while not session.all_done():
        res = session.step()
        for b in range(res.batch):
            toks[b].extend(res.row_tokens(b))
            stats[b].extend(res.row_exit_points(b))
    return toks, stats


def _drain_mega(session, first, K):
    toks = [first.row_tokens(b) for b in range(first.batch)]
    stats = [[] for _ in range(first.batch)]
    while not session.all_done():
        res = session.step(num_ticks=K)
        assert res.is_megatick and int(res.ticks) <= K
        for b in range(res.batch):
            toks[b].extend(res.row_tokens(b))
            stats[b].extend(res.row_exit_points(b))
    return toks, stats


# ---------------- token parity: one megatick == K single steps ----------------
@pytest.mark.parametrize("cache", ["dense", "paged"])
@pytest.mark.parametrize("strategy", ["dense", "specee", "tree"])
def test_megatick_token_parity(setup, strategy, cache):
    """``step(num_ticks=K)`` is token-identical to K single ``step()`` calls
    for every strategy on both cache layouts — budget exhaustion lands
    mid-megatick (budget 8, K=3) so the device-side clip is exercised."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=11)
    e = Engine.create(m, params, sw, strategy=_strategy(strategy))
    s1 = e.new_session(cache=cache)
    ref, ref_stats = _drain_single(s1, s1.prefill(prompts, max_new_tokens=8))
    s2 = e.new_session(cache=cache)
    got, got_stats = _drain_mega(s2, s2.prefill(prompts, max_new_tokens=8), 3)
    assert got == ref
    assert got_stats == ref_stats          # per-tick exit stats survive fusion
    assert all(len(t) == 8 for t in got)


@pytest.mark.parametrize("strategy", ["specee", "tree"])
def test_megatick_eos_mid_flight(setup, strategy):
    """A row hitting EOS inside a megatick truncates exactly where the
    host-accounted loop truncates, and the done mask carries on device (the
    row emits nothing for the rest of the megatick)."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=12)
    e = Engine.create(m, params, sw, strategy=_strategy(strategy))
    s = e.new_session()
    ref, _ = _drain_single(s, s.prefill(prompts, max_new_tokens=10))
    # an EOS that fires mid-stream for row 0 (position 4 of its output)
    eos = ref[0][4]
    s1 = e.new_session()
    want, _ = _drain_single(
        s1, s1.prefill(prompts, max_new_tokens=10, eos_token=eos))
    s2 = e.new_session()
    got, _ = _drain_mega(
        s2, s2.prefill(prompts, max_new_tokens=10, eos_token=eos), 4)
    assert got == want
    assert got[0] == ref[0][:ref[0].index(eos) + 1]


def test_megatick_result_contract(setup):
    """The widened StepResult: (B, K·W) tokens, (B, K) per-tick stat planes,
    tick_counts summing to counts, tick_live consistent with ticks run."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=13)
    K = 4
    strat = TreeStrategy(tree=TreeSpec(depth=2, branch=3))
    e = Engine.create(m, params, sw, strategy=strat)
    s = e.new_session()
    s.prefill(prompts, max_new_tokens=16)
    res = s.step(num_ticks=K)
    B, W = 2, e.emit_width
    assert res.tokens.shape == (B, K * W)
    assert res.counts.shape == (B,)
    assert res.exit_layer.shape == (B, K)
    assert res.accept_len.shape == (B, K)
    assert res.exited.shape == (B, K)
    assert res.tick_counts.shape == (B, K)
    assert res.tick_live.shape == (B, K)
    assert 1 <= int(res.ticks) <= K
    np.testing.assert_array_equal(res.tick_counts.sum(axis=1), res.counts)
    # ticks beyond the early exit are not live for anyone
    for t in range(int(res.ticks), K):
        assert not res.tick_live[:, t].any()


# ---------------- buffer donation ----------------
def test_donation_no_alias_corruption(setup):
    """The step jits donate the decode state (KV cache included): a cache
    reference retained across a step must either fail LOUDLY on read
    (buffer donated and deleted) or still hold the pre-step values (backend
    ignored the donation) — silent aliasing corruption is the one outcome
    that must never happen."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=14)
    e = Engine.create(m, params, sw, strategy="specee")
    s = e.new_session()
    s.prefill(prompts, max_new_tokens=6)
    retained = jax.tree_util.tree_leaves(s._state.cache)
    snapshot = [np.asarray(x).copy() for x in retained]
    s.step()
    deleted = 0
    for leaf, snap in zip(retained, snapshot):
        try:
            now = np.asarray(leaf)
        except RuntimeError:
            deleted += 1            # donated and deleted: loud, safe
            continue
        np.testing.assert_array_equal(now, snap)
    # the session's CURRENT state stays readable either way
    assert np.asarray(s._state.cache["len"]).min() >= 0
    # the megatick jit donates too: same loud-or-unchanged contract
    s2 = e.new_session()
    s2.prefill(prompts, max_new_tokens=6)
    retained2 = jax.tree_util.tree_leaves(s2._state.cache)
    snapshot2 = [np.asarray(x).copy() for x in retained2]
    s2.step(num_ticks=2)
    for leaf, snap in zip(retained2, snapshot2):
        try:
            now = np.asarray(leaf)
        except RuntimeError:
            continue                # donated and deleted: loud, safe
        np.testing.assert_array_equal(now, snap)
    assert np.asarray(s2._state.cache["len"]).min() >= 0


def test_retained_cache_unaffected_by_megatick_manager(setup):
    """KVCacheManager host bookkeeping (free pages, row pages) stays
    coherent when stepping through megaticks with retirement in between."""
    run, m, params, sw = setup
    e = Engine.create(m, params, sw, strategy="specee")
    s = e.new_session(batch=2, cache="paged")
    mgr = s.cache_mgr
    free0 = mgr.free_pages
    s.prefill_row(0, np.asarray(_prompts(run, seed=15))[0],
                  max_new_tokens=4)
    assert mgr.free_pages < free0
    while not s.all_done():
        s.step(num_ticks=2)
    s.retire_row(0)
    assert mgr.free_pages == free0
    # a megatick after retirement keeps the retired row's span pinned at 0
    s.prefill_row(1, np.asarray(_prompts(run, seed=16))[1],
                  max_new_tokens=3)
    while not s.all_done():
        s.step(num_ticks=2)
    assert s.row_span(0) == 0


# ---------------- async pipeline ----------------
def test_finish_step_preserves_readmitted_row(setup):
    """Host bookkeeping edited between a megatick's dispatch and its finish
    (retire + re-admit of a slot) must survive the finish's host sync — the
    dispatch-time carry predates the edit, so syncing it wholesale would
    mark the NEW occupant done with the OLD occupant's emitted count."""
    run, m, params, sw = setup
    e = Engine.create(m, params, sw, strategy="specee")
    s = e.new_session(batch=2, cache="paged")
    p = np.asarray(_prompts(run, seed=19))
    s.prefill_row(0, p[0], max_new_tokens=2)
    s.prefill_row(1, p[1], max_new_tokens=8)
    h1 = s.step_async(4)            # row 0 exhausts its budget mid-megatick
    h2 = s.step_async(4)            # dispatched before h1 is read
    r1 = s.finish_step(h1)
    assert r1.done[0]
    s.retire_row(0)
    s.prefill_row(0, p[0], max_new_tokens=8)   # re-admit: h2 still in flight
    assert not s._done[0]
    s.finish_step(h2)               # h2's carry predates the re-admission
    assert not s._done[0], "finish rolled a re-admitted row back to done"
    assert s._emitted[0] <= 1, "re-admitted row inherited old emitted count"
    assert not s.all_done()
    while not s.all_done():         # and the new occupant decodes to budget
        s.step(num_ticks=4)
    assert s._emitted[0] == 8


def test_step_async_pipeline_parity(setup):
    """Two megaticks dispatched back-to-back (N+1 before N's results are
    read) emit exactly what two synchronous megaticks emit — the
    device-resident carry makes dispatch-ahead safe."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=17)
    e = Engine.create(m, params, sw, strategy="specee")
    s1 = e.new_session()
    s1.prefill(prompts, max_new_tokens=9)
    sync = []
    while not s1.all_done():
        res = s1.step(num_ticks=2)
        sync.append([res.row_tokens(b) for b in range(2)])
    s2 = e.new_session()
    s2.prefill(prompts, max_new_tokens=9)
    h1 = s2.step_async(2)
    h2 = s2.step_async(2)           # dispatched before h1 is read
    r1, r2 = s2.finish_step(h1), s2.finish_step(h2)
    assert [r1.row_tokens(b) for b in range(2)] == sync[0]
    assert [r2.row_tokens(b) for b in range(2)] == sync[1]
    # out-of-order finish is rejected loudly
    h3 = s2.step_async(2)
    h4 = s2.step_async(2)
    with pytest.raises(AssertionError):
        s2.finish_step(h4)
    s2.finish_step(h3)
    s2.finish_step(h4)


@pytest.mark.parametrize("strategy", ["specee", "tree"])
def test_serving_megatick_matches_blocking(setup, strategy):
    """End-to-end serving parity: megatick-K async-pipelined engine emits
    the same per-request tokens as the historical per-tick blocking engine,
    across retire + re-admit waves, with zero page leak."""
    run, m, params, sw = setup
    rng = np.random.default_rng(18)
    prompts = [rng.integers(0, run.model.vocab_size,
                            int(rng.integers(4, 10))) for _ in range(4)]
    outs = {}
    for megatick in (1, 4):
        se = ServingEngine(m, params, sw, strategy=_strategy(strategy),
                           megatick=megatick)
        reqs = [se.submit(p, max_new_tokens=6) for p in prompts]
        se.run_to_completion()
        assert all(r.done and len(r.output) == 6 for r in reqs)
        outs[megatick] = [r.output for r in reqs]
        mgr = se.session.cache_mgr
        assert mgr.free_pages == mgr.num_pages, "page leak under megatick"
    assert outs[4] == outs[1]
