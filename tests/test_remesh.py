"""Elastic remesh on device loss (DESIGN.md §10).

The tentpole invariant: a ``device_lost`` fault inside a sharded engine is
NOT a kill — the engine drains its in-flight megatick, consults
``plan_replica_remesh`` for the largest TP degree over the survivors,
rebuilds its Engine/session/scheduler in place, and re-admits every
unfinished request with verified replay. The degraded run must be
TOKEN-IDENTICAL (outputs AND per-request stats) to a fault-free single-
engine reference, leak zero pages, and leave a ``FaultEvent(action=
"remesh")`` in the log. Only when no factorization remains (unsharded
engine, no devices left) does the fault surface as
``ServingFault(site="device_lost")`` — standalone that's terminal; under a
``ReplicaPool`` it falls back to PR 9 kill-and-requeue, and the death of
the last replica still raises ``ServingFault(site="replica_pool")``.

Two layers of coverage:
  * in-process: ``ServingEngine.remesh(None)`` exercises the exact
    drain → rebuild → replay machinery (an unsharded engine remeshing to
    itself) across dense/specee/tree × dense/paged × kill tick {1,2,3}
    without needing a multi-device runtime;
  * subprocess (``--xla_force_host_platform_device_count``): real TP=2
    meshes losing a device mid-flight, standalone and under a 2-replica
    pool, remeshing to TP=1 with full parity.

Satellites ride along: deadline shedding + load-shed rejection
(degraded-mode serving), the ``FaultLog`` bounded ring + JSONL export,
and engine-level ``cancel``.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import DenseStrategy
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.runtime import faultinject
from repro.runtime.faultinject import FaultSchedule
from repro.serving import (FaultEvent, FaultLog, LoadShedPolicy, ReplicaPool,
                           ServingEngine, ServingFault)


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    run = dataclasses.replace(
        run, serve=dataclasses.replace(run.serve, max_batch=3))
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _prompts(run, n=4, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(4, 12)))
            for _ in range(n)]


def _outputs(se):
    return {r.uid: list(r.output) for r in se.completed}


def _stats(se):
    return {r.uid: (list(r.exit_points), list(r.accept_lens))
            for r in se.completed}


def _assert_no_leak(se):
    mgr = se.session.cache_mgr
    if mgr.kind == "paged":
        assert mgr.free_pages == mgr.num_pages, \
            f"page leak: {mgr.free_pages}/{mgr.num_pages} free"


# ---------------- in-process: the rebuild+replay machinery ----------------
@pytest.mark.parametrize("strategy", ["dense", "specee", "tree"])
@pytest.mark.parametrize("cache", ["dense", "paged"])
def test_remesh_rebuild_replay_parity(setup, strategy, cache):
    """``remesh(None)`` mid-flight (the TP=1 -> TP=1 degenerate rebuild) is
    token- and stats-identical to a fault-free run for every kill tick in
    {1, 2, 3} — the drain/readmit/verified-replay core the device-loss path
    runs, minus the mesh swap (covered by the subprocess tests)."""
    run, m, params, sw = setup
    prompts = _prompts(run)

    def serve(remesh_at=None):
        se = ServingEngine(m, params, sw, strategy=strategy, megatick=2,
                           cache=cache)
        for p in prompts:
            se.submit(p, max_new_tokens=8)
        if remesh_at is not None:
            for _ in range(remesh_at):
                se.step()
            se.remesh(None, site="test", detail=f"tick{remesh_at}")
        se.run_to_completion()
        se.close()
        return se

    ref = serve()
    assert not ref.fault_log
    for kill_tick in (1, 2, 3):
        se = serve(remesh_at=kill_tick)
        assert _outputs(se) == _outputs(ref), (strategy, cache, kill_tick)
        assert _stats(se) == _stats(ref), (strategy, cache, kill_tick)
        events = [e for e in se.fault_log if e.action == "remesh"]
        assert len(events) == 1 and events[0].site == "test"
        assert "readmitted=" in events[0].detail
        _assert_no_leak(se)
        # replay actually VERIFIED the recorded prefix (not just re-emitted)
        replayed = [r for r in se.completed if r.replay_total]
        assert all(r.replayed == r.replay_total for r in replayed)


def test_remesh_sampled_run_parity(setup):
    """Sampled decode remeshes reproducibly: the rebuilt session re-seeds
    from the engine's original ``prng_seed`` and sample keys are position-
    keyed, so replay verification holds at temperature > 0 too."""
    run, m, params, sw = setup
    prompts = _prompts(run, n=3, seed=23)
    strat = DenseStrategy(temperature=1.0)

    def serve(remesh_at=None):
        se = ServingEngine(m, params, sw, strategy=strat, megatick=2,
                           prng_seed=7)
        for p in prompts:
            se.submit(p, max_new_tokens=8)
        if remesh_at is not None:
            for _ in range(remesh_at):
                se.step()
            se.remesh(None, site="test")
        se.run_to_completion()
        se.close()
        return se

    ref = serve()
    se = serve(remesh_at=2)
    assert _outputs(se) == _outputs(ref)
    _assert_no_leak(se)


# ---------------- device_lost: no-survivor fallback ladder ----------------
def test_device_lost_unsharded_engine_raises(setup):
    """An unsharded engine has no surviving devices to remesh onto: the
    injected loss drains what it can and surfaces site="device_lost" with a
    give_up (NOT remesh) fault event."""
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="specee", megatick=2)
    for p in _prompts(run, n=2):
        se.submit(p, max_new_tokens=6)
    with faultinject.injected(FaultSchedule.once("device_lost", visit=1)):
        with pytest.raises(ServingFault) as ei:
            se.run_to_completion()
    assert ei.value.site == "device_lost"
    assert any(e.action == "give_up" for e in se.fault_log)
    assert not any(e.action == "remesh" for e in se.fault_log)
    se.close()


def test_device_lost_pool_fallback_kill_and_requeue(setup):
    """Under a pool, an engine that CANNOT remesh falls back to PR 9
    kill-and-requeue: the survivor replays the dead replica's tokens and
    the final outputs still match a fault-free single-engine run."""
    run, m, params, sw = setup
    prompts = _prompts(run)

    ref = ServingEngine(m, params, sw, strategy="specee", megatick=2)
    for p in prompts:
        ref.submit(p, max_new_tokens=8)
    ref.run_to_completion()
    ref.close()
    ref_out = [list(r.output) for r in sorted(ref.completed,
                                              key=lambda r: r.uid)]

    pool = ReplicaPool([
        ServingEngine(m, params, sw, strategy="specee", megatick=2)
        for _ in range(2)])
    prs = [pool.submit(p, max_new_tokens=8) for p in prompts]
    with faultinject.injected(
            FaultSchedule.once("device_lost", visit=1)) as inj:
        pool.run_to_completion()
    assert inj.fired_sites() == frozenset({"device_lost"})
    assert sorted(pool.alive) == [False, True]
    kills = [e for e in pool.fault_log if e.action == "kill_replica"]
    assert kills and kills[0].site == "device_lost"
    assert not any(e.action == "remesh" for e in pool.fault_log)
    assert sum(pr.migrations for pr in prs) >= 1
    assert [list(pr.output) for pr in prs] == ref_out
    assert pool.degraded and pool.health.replicas_live == 1
    pool.close()


def test_device_lost_last_replica_raises_replica_pool(setup):
    """Exhausting the ladder entirely (single unsharded replica, device
    lost) still surfaces the PR 9 terminal fault: site="replica_pool"."""
    run, m, params, sw = setup
    pool = ReplicaPool([ServingEngine(m, params, sw, strategy="specee",
                                      megatick=2)])
    for p in _prompts(run, n=2):
        pool.submit(p, max_new_tokens=6)
    with faultinject.injected(FaultSchedule.once("device_lost", visit=1)):
        with pytest.raises(ServingFault) as ei:
            pool.run_to_completion()
    assert ei.value.site == "replica_pool"


# ---------------- degraded-mode serving: deadlines + load shedding -------
def test_deadline_shed(setup):
    """Requests past their deadline are SHED with a structured fault —
    queued or slotted — while undeadlined work completes normally and the
    cancelled rows leak no pages."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    engine = ServingEngine(m, params, sw, strategy="specee", megatick=1,
                           prefill_chunk=0)
    pool = ReplicaPool([engine])
    shed = [pool.submit(prompts[i], max_new_tokens=48, deadline_ticks=3)
            for i in range(2)]
    kept = [pool.submit(prompts[i], max_new_tokens=6) for i in (2, 3)]
    pool.run_to_completion()
    for pr in shed:
        assert pr.failed and pr.done
        assert pr.fault is not None and pr.fault.site == "deadline"
        assert 0 < len(pr.output) < 48      # partial progress retained
    for pr in kept:
        assert pr.done and not pr.failed and len(pr.output) == 6
    assert pool.failed == shed
    assert [pr.uid for pr in pool.completed] == [pr.uid for pr in kept]
    sheds = [e for e in pool.fault_log if e.site == "deadline"]
    assert len(sheds) == 2 and all(e.action == "shed" for e in sheds)
    _assert_no_leak(engine)
    pool.close()


def test_deadline_generous_completes(setup):
    """A deadline the request beats is a no-op: no shed, no fault."""
    run, m, params, sw = setup
    pool = ReplicaPool([ServingEngine(m, params, sw, strategy="specee",
                                      megatick=2)])
    prs = [pool.submit(p, max_new_tokens=4, deadline_ticks=500)
           for p in _prompts(run, n=2)]
    pool.run_to_completion()
    assert all(pr.done and not pr.failed for pr in prs)
    assert not pool.failed and not pool.fault_log
    pool.close()


def test_load_shed_bounded_queue(setup):
    """``only_degraded=False`` bounds intake unconditionally: the queue
    admits up to max_queue, rejects beyond it with site="load_shed", and
    admits again once a pool tick drains the queue onto replicas."""
    run, m, params, sw = setup
    prompts = _prompts(run, n=3)
    pool = ReplicaPool(
        [ServingEngine(m, params, sw, strategy="specee", megatick=2)],
        shed=LoadShedPolicy(max_queue=1, only_degraded=False))
    pool.submit(prompts[0], max_new_tokens=4)
    with pytest.raises(ServingFault) as ei:
        pool.submit(prompts[1], max_new_tokens=4)
    assert ei.value.site == "load_shed"
    assert any(e.site == "load_shed" and e.action == "reject"
               for e in pool.fault_log)
    pool.step()                             # drains the queue onto slots
    pool.submit(prompts[2], max_new_tokens=4)
    done = pool.run_to_completion()
    assert len(done) == 2                   # the rejected one never entered
    pool.close()


def test_load_shed_only_when_degraded(setup):
    """The default policy sheds only while degraded: a healthy pool admits
    freely; after a replica death the same bound rejects."""
    run, m, params, sw = setup
    prompts = _prompts(run, n=3)
    pool = ReplicaPool(
        [ServingEngine(m, params, sw, strategy="specee", megatick=2)
         for _ in range(2)],
        shed=LoadShedPolicy(max_queue=0, only_degraded=True))
    assert not pool.degraded
    pool.submit(prompts[0], max_new_tokens=4)   # healthy: bound inactive
    pool.step()
    pool.kill_replica(1, reason="test")
    assert pool.degraded
    with pytest.raises(ServingFault) as ei:
        pool.submit(prompts[1], max_new_tokens=4)
    assert ei.value.site == "load_shed"
    pool.run_to_completion()
    pool.close()


def test_pool_health_snapshot(setup):
    run, m, params, sw = setup
    pool = ReplicaPool([ServingEngine(m, params, sw, strategy="specee")])
    h = pool.health
    assert (h.replicas_total, h.replicas_live) == (1, 1)
    assert h.tp_degrees == (1,) and h.built_tp_degrees == (1,)
    assert h.queued == 0 and h.degraded is False
    pool.close()


# ---------------- FaultLog ring + JSONL export ----------------
def test_fault_log_ring_bounds_and_counts():
    log = FaultLog(cap=4)
    assert not log and len(log) == 0 and log.dropped == 0
    for i in range(7):
        log.append(FaultEvent(site="health", tick=i, action="x"))
    assert len(log) == 4 and log.total == 7 and log.dropped == 3
    assert [e.tick for e in log] == [3, 4, 5, 6]
    assert log[0].tick == 3 and log[-1].tick == 6
    assert [e.tick for e in log[1:3]] == [4, 5]
    with pytest.raises(ValueError):
        FaultLog(cap=0)


def test_fault_log_dump_jsonl(tmp_path):
    log = FaultLog(cap=3)
    log.extend(FaultEvent(site="evict", tick=i, action="evict",
                          detail=f"row={i}") for i in range(5))
    path = str(tmp_path / "faults.jsonl")
    assert log.dump_jsonl(path, source="engine") == 3
    rows = [json.loads(l) for l in open(path)]
    # seq preserves the GLOBAL index: 2 dropped events leave a visible gap
    assert [r["seq"] for r in rows] == [2, 3, 4]
    assert rows[0] == {"seq": 2, "source": "engine", "site": "evict",
                       "tick": 2, "action": "evict", "detail": "row=2"}
    other = FaultLog()
    other.append(FaultEvent(site="deadline", tick=9, action="shed"))
    assert other.dump_jsonl(path, source="pool", append=True) == 1
    rows = [json.loads(l) for l in open(path)]
    assert len(rows) == 4 and rows[-1]["source"] == "pool"


# ---------------- engine-level cancel ----------------
def test_engine_cancel_queued_and_slotted(setup):
    run, m, params, sw = setup
    prompts = _prompts(run)
    se = ServingEngine(m, params, sw, strategy="specee", megatick=1,
                       prefill_chunk=0)
    reqs = [se.submit(p, max_new_tokens=6) for p in prompts]
    assert se.cancel(reqs[3].uid) is True       # still queued: withdrawn
    assert se.cancel(999) is False              # unknown uid
    se.step()                                   # admits 0..2 into slots
    assert se.cancel(reqs[0].uid) is True       # slotted: row retired
    se.run_to_completion()
    se.close()
    assert sorted(r.uid for r in se.completed) == [reqs[1].uid, reqs[2].uid]
    _assert_no_leak(se)


# ---------------- subprocess: real TP meshes losing a device -------------
def _run_subprocess(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", script],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    return r.stdout + r.stderr


_TP2_REMESH = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import jax
import numpy as np
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.runtime import faultinject
from repro.runtime.faultinject import FaultSchedule
from repro.serving import ServingEngine
from repro.sharding.compat import make_mesh

run = get_config("llama2-7b").smoke()
run = dataclasses.replace(
    run, serve=dataclasses.replace(run.serve, max_batch=3))
m = build_model(run)
params = m.init(jax.random.PRNGKey(0))
sw = eng.init_specee(m, jax.random.PRNGKey(1))
rng = np.random.default_rng(5)
prompts = [rng.integers(0, run.model.vocab_size, int(rng.integers(4, 12)))
           for _ in range(4)]


def serve(cache, mesh=None):
    se = ServingEngine(m, params, sw, strategy="specee", megatick=2,
                       cache=cache, mesh=mesh)
    for p in prompts:
        se.submit(p, max_new_tokens=8)
    se.run_to_completion()
    se.close()
    return se


def outputs(se):
    return {r.uid: list(r.output) for r in se.completed}


def stats(se):
    return {r.uid: (list(r.exit_points), list(r.accept_lens))
            for r in se.completed}


for cache in ("dense", "paged"):
    ref = serve(cache)              # fault-free UNSHARDED reference
    for kill_tick in (1, 2, 3):
        mesh = make_mesh((1, 2), ("data", "model"),
                         devices=jax.devices()[:2])
        with faultinject.injected(
                FaultSchedule.once("device_lost", visit=kill_tick)) as inj:
            se = serve(cache, mesh=mesh)
        assert inj.fired_sites() == frozenset({"device_lost"}), inj.fired
        assert se.tp_degree == 1, se.tp_degree
        ev = [e for e in se.fault_log if e.action == "remesh"]
        assert len(ev) == 1 and ev[0].site == "device_lost", list(se.fault_log)
        assert "tp 2->1" in ev[0].detail, ev[0].detail
        assert not any(e.action == "give_up" for e in se.fault_log)
        assert outputs(se) == outputs(ref), (cache, kill_tick)
        assert stats(se) == stats(ref), (cache, kill_tick)
        mgr = se.session.cache_mgr
        if mgr.kind == "paged":
            assert mgr.free_pages == mgr.num_pages, \\
                (mgr.free_pages, mgr.num_pages)
        print("ok", cache, kill_tick)
print("TP2-REMESH-OK")
"""


def test_device_lost_tp2_remeshes_to_tp1_subprocess():
    """The acceptance run: a TP=2 engine loses a device at tick {1,2,3}
    (dense AND paged cache) and remeshes to TP=1 — bit-identical tokens and
    stats vs the fault-free unsharded reference, zero page leak, exactly one
    FaultEvent(action="remesh"), no give_up/kill."""
    out = _run_subprocess(_TP2_REMESH)
    assert "TP2-REMESH-OK" in out, out


_POOL_REMESH = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax
import numpy as np
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.runtime import faultinject
from repro.runtime.faultinject import FaultSchedule
from repro.launch.mesh import make_replica_meshes
from repro.serving import ReplicaPool, ServingEngine

run = get_config("llama2-7b").smoke()
run = dataclasses.replace(
    run, serve=dataclasses.replace(run.serve, max_batch=3))
m = build_model(run)
params = m.init(jax.random.PRNGKey(0))
sw = eng.init_specee(m, jax.random.PRNGKey(1))
rng = np.random.default_rng(7)
prompts = [rng.integers(0, run.model.vocab_size, int(rng.integers(4, 12)))
           for _ in range(4)]

ref = ServingEngine(m, params, sw, strategy="specee", megatick=2)
for p in prompts:
    ref.submit(p, max_new_tokens=8)
ref.run_to_completion()
ref.close()
ref_out = [list(r.output) for r in sorted(ref.completed,
                                          key=lambda r: r.uid)]

meshes = make_replica_meshes(2, 2)
pool = ReplicaPool([ServingEngine(m, params, sw, strategy="specee",
                                  megatick=2, mesh=ms) for ms in meshes])
assert pool.health.degraded is False
assert pool.health.tp_degrees == (2, 2), pool.health
prs = [pool.submit(p, max_new_tokens=8) for p in prompts]
with faultinject.injected(
        FaultSchedule.once("device_lost", visit=2)) as inj:
    pool.run_to_completion()
assert inj.fired_sites() == frozenset({"device_lost"})
# a remesh, NOT a kill: both replicas alive, one degraded to TP=1
assert pool.alive == [True, True], pool.alive
assert sorted(pool.health.tp_degrees) == [1, 2], pool.health
assert pool.health.degraded is True
assert all(pr.migrations == 0 for pr in prs)
assert any(e.action == "remesh" and e.site == "device_lost"
           for e in pool.fault_log)
assert any(e.action == "degraded" and e.site == "health"
           for e in pool.fault_log)
assert not any(e.action == "kill_replica" for e in pool.fault_log)
assert [list(pr.output) for pr in prs] == ref_out, "token divergence"
for rep in pool.replicas:
    mgr = rep.session.cache_mgr
    if mgr.kind == "paged":
        assert mgr.free_pages == mgr.num_pages
pool.close()
print("POOL-REMESH-OK")
"""


def test_device_lost_under_pool_remeshes_in_place_subprocess():
    """A 2x TP=2 pool absorbs a device loss as an IN-PLACE remesh of the
    affected replica (alive stays [True, True], zero migrations), the pool
    flips to degraded exactly once, and outputs match the fault-free
    unsharded single-engine reference."""
    out = _run_subprocess(_POOL_REMESH)
    assert "POOL-REMESH-OK" in out, out
