"""Direct unit tests for repro.runtime.fault and repro.runtime.faultinject.

The StragglerMonitor / plan_remesh logic was previously covered only
indirectly through launcher smoke runs; these pin the edge cases (median/MAD
on even-length fleets, a straggler not inflating its own threshold, the
re-mesh degradation ladder) and the PreemptionGuard install/uninstall
contract the serving engine now relies on.
"""
import os
import signal

import pytest

from repro.runtime import faultinject
from repro.runtime.fault import (PreemptionGuard, StragglerMonitor,
                                 plan_remesh, plan_replica_remesh)
from repro.runtime.faultinject import (FaultInjector, FaultSchedule,
                                       InjectedFault)


# ---------------- StragglerMonitor ----------------
def _feed(mon, host, value, n=None):
    for _ in range(n if n is not None else mon.min_samples):
        mon.record(host, value)


def test_fleet_stats_needs_two_hosts():
    mon = StragglerMonitor()
    assert mon.fleet_stats() == (0.0, 0.0)
    _feed(mon, 0, 1.0)
    assert mon.fleet_stats() == (0.0, 0.0)     # one host: no fleet yet
    assert mon.stragglers() == []


def test_fleet_stats_even_fleet_median():
    """Even-length fleets take the upper-median element (sorted[n//2]) for
    both location and scale — pinned so a refactor to mean-of-middle-two
    shows up as a test change, not a silent behavior shift."""
    mon = StragglerMonitor()
    for host, v in enumerate([1.0, 2.0, 3.0, 4.0]):
        _feed(mon, host, v)
    med, mad = mon.fleet_stats()
    assert med == pytest.approx(3.0)            # sorted[4 // 2]
    assert mad == pytest.approx(1.0)            # |v - 3| = [2, 1, 0, 1]


def test_straggler_does_not_inflate_own_threshold():
    """Median-based location/scale: one wildly slow host must still be
    flagged (a mean-based threshold would chase the outlier)."""
    mon = StragglerMonitor(sigma=3.0)
    for host in range(6):
        _feed(mon, host, 1.0)
    _feed(mon, 6, 50.0)
    assert mon.stragglers() == [6]
    med, _ = mon.fleet_stats()
    assert med == pytest.approx(1.0)            # fleet median unmoved


def test_min_samples_filters_cold_hosts():
    mon = StragglerMonitor(min_samples=8)
    for host in range(4):
        _feed(mon, host, 1.0)
    mon.record(9, 100.0)                        # 1 sample: not yet trusted
    assert mon.stragglers() == []
    _feed(mon, 9, 100.0)
    assert mon.stragglers() == [9]


# ---------------- plan_remesh ----------------
def test_plan_remesh_shrinks_data_parallel():
    assert plan_remesh(64, 8) == (8, 8)
    assert plan_remesh(63, 8) == (7, 8)         # one lost host: DP 8 -> 7
    assert plan_remesh(8, 8) == (1, 8)


def test_plan_remesh_multi_pod_ladder():
    """The full multi-pod degradation ladder. Survivors are physically
    spread across pods (a TP group cannot straddle the pod boundary), so 12
    alive over 2 pods is 6+6 — no pod holds a whole TP-8 group, and the old
    recursion that retried the SAME 12 devices as one imaginary pod was a
    bug, not a fallback."""
    assert plan_remesh(64, 8, pods=4) == (4, 2, 8)
    # uneven losses: rectangular mesh at the MINIMUM surviving group count
    assert plan_remesh(64, 8, pods=4, pod_alive=(16, 16, 16, 9)) == (4, 1, 8)
    # one pod lost entirely: the usable pods carry on
    assert plan_remesh(48, 8, pods=4, pod_alive=(16, 16, 16, 0)) == (3, 2, 8)
    # a single pod with >= 1 group left degrades to a single-pod mesh
    assert plan_remesh(12, 8, pods=2, pod_alive=(9, 3)) == (1, 8)
    # evenly-spread 12 over 2 pods is 6+6: no pod holds a TP-8 group
    assert plan_remesh(12, 8, pods=2) is None
    assert plan_remesh(4, 8, pods=2) is None


def test_plan_remesh_none_when_tp_group_lost():
    assert plan_remesh(7, 8) is None
    # degenerate: fewer alive devices than the TP degree in every pod
    assert plan_remesh(14, 8, pods=2) is None       # 7+7
    # 8+7: exactly one pod still holds a group -> single-pod (1, 8)
    assert plan_remesh(15, 8, pods=2) == (1, 8)


def test_plan_replica_remesh_tp_ladder():
    """Serving remesh (one replica, data pinned at 1): the largest DIVISOR
    of the built TP degree that fits the survivors, down to unsharded."""
    assert plan_replica_remesh(3, 4) == 2           # 4 -> 2 (3 alive)
    assert plan_replica_remesh(2, 4) == 2
    assert plan_replica_remesh(1, 4) == 1           # down to unsharded
    assert plan_replica_remesh(1, 2) == 1
    assert plan_replica_remesh(4, 4) == 4           # nothing actually lost
    assert plan_replica_remesh(5, 6) == 3           # divisors only: not 5
    assert plan_replica_remesh(0, 2) is None        # no device left
    assert plan_replica_remesh(0, 1) is None


# ---------------- PreemptionGuard ----------------
def test_guard_install_idempotent_and_uninstall_restores():
    before = signal.getsignal(signal.SIGTERM)
    g = PreemptionGuard()
    g.install()
    installed = signal.getsignal(signal.SIGTERM)
    assert installed is not before
    g.install()                                 # idempotent: same handler
    assert signal.getsignal(signal.SIGTERM) is installed
    g.uninstall()
    assert signal.getsignal(signal.SIGTERM) is before
    g.uninstall()                               # no-op when not installed
    assert signal.getsignal(signal.SIGTERM) is before


def test_guard_catches_sigterm_and_nests():
    outer, inner = PreemptionGuard(), PreemptionGuard()
    outer.install()
    inner.install()
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        assert inner.should_save()
        assert outer.should_save()              # handlers chain outward
    finally:
        inner.uninstall()
        outer.uninstall()


# ---------------- faultinject ----------------
def test_schedule_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSchedule.once("warp_core_breach")


def test_injector_counts_visits_per_site():
    inj = FaultInjector(FaultSchedule.at(dispatch=[1], nan_logits=[0]))
    assert inj.fire("dispatch") is False        # visit 0
    assert inj.fire("nan_logits") is True       # visit 0 (independent count)
    assert inj.fire("dispatch") is True         # visit 1
    assert inj.fire("dispatch") is False        # visit 2
    assert inj.fired == [("nan_logits", 0), ("dispatch", 1)]
    assert inj.fired_sites() == frozenset({"dispatch", "nan_logits"})


def test_check_raises_with_site_and_visit():
    inj = FaultInjector(FaultSchedule.once("dispatch"))
    with pytest.raises(InjectedFault) as ei:
        inj.check("dispatch")
    assert ei.value.site == "dispatch" and ei.value.visit == 0


def test_seeded_schedule_deterministic():
    a = FaultSchedule.seeded(seed=42, rate=0.2, horizon=64)
    b = FaultSchedule.seeded(seed=42, rate=0.2, horizon=64)
    c = FaultSchedule.seeded(seed=43, rate=0.2, horizon=64)
    assert a.plan == b.plan
    assert a.plan != c.plan
    assert any(a.plan.values())                 # rate 0.2 over 64: non-empty


def test_module_level_noop_without_injector():
    faultinject.uninstall()
    assert faultinject.fire("dispatch") is False
    faultinject.check("dispatch")               # no raise
    with faultinject.injected(FaultSchedule.once("dispatch")) as inj:
        assert faultinject.active() is inj
        with pytest.raises(InjectedFault):
            faultinject.check("dispatch")
    assert faultinject.active() is None
