"""Substrate tests: optimizer/schedules, data pipeline resumability,
checkpoint crash-safety, fault tolerance, serving engine, collectives."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model


# ---------------- optimizer + schedules ----------------
def test_adamw_reduces_loss():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    from repro.train import TrainLoop
    loop = TrainLoop(m, run, params)
    losses = []
    for _ in range(8):
        losses.append(loop.run_steps(1)["loss"])
    assert losses[-1] < losses[0], losses


def test_schedules():
    from repro.optim import make_schedule
    import dataclasses
    base = get_config("llama2-7b").train
    for name in ("cosine", "wsd", "constant"):
        cfg = dataclasses.replace(base, schedule=name, steps=100,
                                  warmup_steps=10, learning_rate=1e-3)
        s = make_schedule(cfg)
        assert float(s(0)) == 0.0 or float(s(0)) < 1e-3
        assert float(s(10)) == pytest.approx(1e-3, rel=0.01)
        if name == "wsd":
            # stable plateau then decay
            assert float(s(50)) == pytest.approx(1e-3, rel=0.01)
            assert float(s(99)) < 0.5e-3
        if name == "cosine":
            assert float(s(99)) < float(s(40))


def test_grad_accumulation_matches_full_batch():
    import dataclasses
    from repro.train import make_train_step
    from repro.optim import adamw_init
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                          run.model.vocab_size)}
    full = make_train_step(m, dataclasses.replace(run.train, microbatch=0))
    acc = make_train_step(m, dataclasses.replace(run.train, microbatch=2))
    p1, _, s1 = full(params, adamw_init(params), batch)
    p2, _, s2 = acc(params, adamw_init(params), batch)
    assert s1["loss"] == pytest.approx(s2["loss"], rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------- data pipeline ----------------
def test_pipeline_deterministic_resume():
    from repro.data import DataPipeline
    cfg = get_config("llama2-7b").smoke().model
    p1 = DataPipeline(cfg, 4, 32, seed=7)
    b1 = [p1.next() for _ in range(5)]
    state = p1.state_dict()
    b_next = p1.next()
    # resume from the saved state reproduces the stream exactly
    p2 = DataPipeline.from_state(cfg, 4, 32, state)
    np.testing.assert_array_equal(p2.next()["tokens"], b_next["tokens"])
    # full restart reproduces from scratch
    p3 = DataPipeline(cfg, 4, 32, seed=7)
    np.testing.assert_array_equal(p3.next()["tokens"], b1[0]["tokens"])


def test_pipeline_modalities():
    from repro.data import DataPipeline
    for arch in ("hubert-xlarge", "internvl2-26b"):
        cfg = get_config(arch).smoke().model
        b = DataPipeline(cfg, 2, 16).next()
        if cfg.frontend == "audio_frames":
            assert set(b) == {"frames", "targets", "mask"}
        else:
            assert set(b) == {"tokens", "patches"}


# ---------------- checkpointing ----------------
def test_checkpoint_roundtrip_and_gc():
    from repro.checkpoint import CheckpointManager
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2, async_save=False)
        for s in (1, 2, 3):
            cm.save(s, jax.tree_util.tree_map(lambda x: x * s, tree),
                    extra={"data": {"seed": 0, "data_step": s}})
        assert cm.all_steps() == [2, 3]  # gc keeps 2
        got, extra = cm.restore(3, tree)
        np.testing.assert_allclose(got["a"], np.arange(10.0) * 3)
        assert extra["data"]["data_step"] == 3


def test_checkpoint_crash_safety():
    """An uncommitted (crashed) save must be invisible to restore_latest."""
    from repro.checkpoint import CheckpointManager
    tree = {"a": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, async_save=False)
        cm.save(1, tree)
        # simulate a crash mid-save: step dir without COMMITTED
        os.makedirs(os.path.join(d, "step_000000002"))
        with open(os.path.join(d, "step_000000002", "manifest.json"),
                  "w") as f:
            f.write("{}")
        step, got, _ = cm.restore_latest(tree)
        assert step == 1


def test_train_restart_reproduces_stream():
    import dataclasses
    from repro.train import TrainLoop
    run = get_config("llama2-7b").smoke()
    # disable periodic auto-saves so the manual save at step 2 stays latest
    run = dataclasses.replace(run,
                              train=dataclasses.replace(run.train,
                                                        checkpoint_every=100))
    m = build_model(run)
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(m, run, m.init(jax.random.PRNGKey(0)), ckpt_dir=d)
        loop.run_steps(2)
        loop.save()
        loop.ckpt.wait()
        loop.run_steps(1)
        loss_after_3 = loop.history[-1]["loss"]
        # crash & restart from step 2
        loop2 = TrainLoop(m, run, m.init(jax.random.PRNGKey(5)), ckpt_dir=d)
        assert loop2.try_restore()
        assert loop2.step == 2
        loop2.run_steps(1)
        assert loop2.history[-1]["loss"] == pytest.approx(loss_after_3,
                                                          rel=1e-5)


# ---------------- fault tolerance ----------------
def test_straggler_monitor():
    from repro.runtime.fault import StragglerMonitor
    mon = StragglerMonitor(min_samples=4)
    for t in range(10):
        for h in range(8):
            mon.record(h, 1.0 + (3.0 if h == 5 else 0.0)
                       + 0.01 * np.random.default_rng(t * 8 + h).random())
    assert mon.stragglers() == [5]


def test_elastic_remesh_plan():
    from repro.runtime.fault import plan_remesh
    assert plan_remesh(256, 16) == (16, 16)
    assert plan_remesh(255, 16) == (15, 16)    # lost a chip -> DP 15
    assert plan_remesh(512, 16, pods=2) == (2, 16, 16)
    assert plan_remesh(300, 16, pods=2) == (2, 9, 16)
    assert plan_remesh(15, 16) is None         # not one TP group left
    assert plan_remesh(31, 16, pods=2) == (1, 16)  # degrade to single pod


# ---------------- serving ----------------
def test_continuous_batching_matches_dense():
    from repro.serving import ServingEngine
    from repro.core import engine as eng
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    prompts = [np.arange(5) % run.model.vocab_size,
               np.arange(9) % run.model.vocab_size,
               (np.arange(3) + 7) % run.model.vocab_size]
    outs = {}
    for mode in (True, False):
        se = ServingEngine(m, params, sw, specee=mode)
        reqs = [se.submit(p, max_new_tokens=n)
                for p, n in zip(prompts, (6, 4, 5))]
        se.run_to_completion()
        outs[mode] = [r.output for r in reqs]
        assert all(r.done for r in reqs)
        assert [len(o) for o in outs[mode]] == [6, 4, 5]
    # untrained predictor never exits unverified: identical greedy streams
    assert outs[True] == outs[False]


def test_serving_queueing_beyond_slots():
    from repro.serving import ServingEngine
    from repro.core import engine as eng
    run = get_config("llama2-7b").smoke()   # max_batch=2 in smoke
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    se = ServingEngine(m, params, sw)
    reqs = [se.submit(np.arange(4 + i) % run.model.vocab_size,
                      max_new_tokens=3) for i in range(5)]
    done = se.run_to_completion()
    assert len(done) == 5 and all(r.done for r in reqs)


# ---------------- collectives (multi-device via subprocess) ----------------
def test_quantize_roundtrip():
    from repro.runtime.collectives import dequantize_int8, quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51


_MULTIDEV = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime.collectives import collective_matmul_ag, compressed_psum
from repro.sharding.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("tp",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
w = jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * 0.1

# x row-sharded over tp; w column-sharded (Megatron column-parallel layout);
# each device ends with full rows x its N-shard -> out_specs P(None, "tp")
f = shard_map(lambda xs, ws: collective_matmul_ag(xs, ws, "tp"),
              mesh=mesh, in_specs=(P("tp", None), P(None, "tp")),
              out_specs=P(None, "tp"))
got = f(x, w)
np.testing.assert_allclose(got.astype(np.float32), (x @ w), atol=1e-4)

g = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
err0 = jnp.zeros((4, 64))

def cpsum(gs, es):
    red, new_err = compressed_psum(gs[0], "tp", es[0])
    return red, new_err[None]

f2 = shard_map(cpsum, mesh=mesh,
               in_specs=(P("tp", None), P("tp", None)),
               out_specs=(P(None), P("tp", None)))
red, err = f2(g, err0)
rel = float(jnp.linalg.norm(red - g.sum(0)) / jnp.linalg.norm(g.sum(0)))
assert rel < 0.05, rel
print("MULTIDEV-OK")
"""


def test_collectives_multidevice():
    # pin cpu explicitly: with libtpu installed, an unset JAX_PLATFORMS
    # makes the child spin in TPU-client discovery instead of running
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MULTIDEV], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert "MULTIDEV-OK" in r.stdout, r.stdout + r.stderr
