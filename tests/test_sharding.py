"""Sharding-policy tests: spec validity (divisibility-aware fallbacks) and an
end-to-end small-mesh compile of the launch path (subprocess, 4 CPU devices).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import build_model


def _mesh11():
    from repro.sharding.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,policy", [
    ("llama2-7b", "tp_dp"), ("command-r-plus-104b", "tp2d"),
    ("qwen3-moe-235b-a22b", "tp2d"), ("minicpm-2b", "fsdp_tp"),
    ("recurrentgemma-9b", "tp_dp"), ("mamba2-130m", "tp_dp"),
])
def test_param_specs_are_valid(arch, policy):
    """Every leaf gets a PartitionSpec whose sharded dims divide the mesh
    extent (checked against the REAL production shapes via eval_shape)."""
    from repro.sharding import param_specs
    run = get_config(arch)
    model = build_model(run)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # fake a 16x16 mesh purely for extent lookups
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    specs = param_specs(model, FakeMesh(), policy, shapes)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            ext = int(np.prod([FakeMesh.shape[a] for a in
                               (ax if isinstance(ax, tuple) else (ax,))]))
            assert dim % ext == 0, f"{arch}: {leaf.shape} vs {spec}"


def test_odd_vocab_falls_back_to_replicated():
    """minicpm's 122753 vocab divides nothing — embedding must not shard V."""
    from repro.sharding import param_specs
    run = get_config("minicpm-2b")
    model = build_model(run)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    specs = param_specs(model, FakeMesh(), "tp_dp", shapes)
    assert tuple(specs["embed"]["tok"])[0] is None


_SMALL_MESH_COMPILE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import build_model, ModelFlags
from repro.launch.specs import input_specs
from repro.launch.dryrun import step_fn_for
from repro.config import ShapeCell

from repro.sharding.compat import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
run = get_config("llama2-7b").smoke()
for cell, kind in [(ShapeCell("train_4k", "train", 32, 4), "train"),
                   (ShapeCell("decode_32k", "decode", 64, 4), "decode")]:
    model = build_model(run, ModelFlags(act_batch_axes="data",
                                        act_batch_extent=2))
    args, specs = input_specs(model, cell, mesh)
    fn = step_fn_for(model, run, cell, data_extent=2,
                     param_pspec=specs[0] if kind == "train" else None)
    in_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    print(kind, "COMPILED")
print("SMALL-MESH-OK")
"""


def test_small_mesh_launch_path_compiles():
    """The dryrun flow (specs -> shardings -> lower -> compile) on a 2x2 CPU
    mesh with the smoke config — CI coverage for the at-scale path."""
    # pin cpu explicitly: with libtpu installed, an unset JAX_PLATFORMS
    # makes the child spin in TPU-client discovery instead of running
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SMALL_MESH_COMPILE],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    assert "SMALL-MESH-OK" in r.stdout, r.stdout + r.stderr


_SHARDED_VERIFY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.compat import make_mesh
from repro.sharding.ctx import ShardCtx
from repro.kernels.exit_gate import ops as gate_lib

key = jax.random.PRNGKey(0)
for degree in (2, 4):
    mesh = make_mesh((1, degree), ("data", "model"),
                     devices=jax.devices()[:degree])
    shard = ShardCtx.from_mesh(mesh)
    for V in (512, 509, 500):       # divisible / odd / pad-needed vocabs
        for impl in ("ref", "xla", "kernel"):
            kb, kh = jax.random.split(jax.random.fold_in(key, V))
            hn = jax.random.normal(kb, (3, 64), jnp.float32)
            w = jax.random.normal(kh, (64, V), jnp.float32)
            t0, v0 = gate_lib.verify_argmax(hn, w, impl=impl)
            t1, v1 = gate_lib.verify_argmax(hn, w, impl=impl, shard=shard)
            assert np.array_equal(np.asarray(t0), np.asarray(t1)), \\
                (degree, V, impl)
            assert np.array_equal(np.asarray(v0), np.asarray(v1)), \\
                (degree, V, impl)
            i0, x0 = gate_lib.verify_topk(hn, w, 4, impl=impl)
            i1, x1 = gate_lib.verify_topk(hn, w, 4, impl=impl, shard=shard)
            assert np.array_equal(np.asarray(i0), np.asarray(i1)), \\
                (degree, V, impl)
            assert np.array_equal(np.asarray(x0), np.asarray(x1)), \\
                (degree, V, impl)
    print("degree", degree, "OK")
# tie-break: duplicated columns force equal maxima on BOTH shards — the
# merge must still pick the lowest global id (jnp.argmax first-occurrence
# contract) for argmax and lower-index-first ordering for top-k
hn = jnp.ones((2, 8), jnp.float32)
w = jnp.tile(jax.random.normal(key, (8, 16), jnp.float32), (1, 2))
mesh = make_mesh((1, 2), ("data", "model"), devices=jax.devices()[:2])
shard = ShardCtx.from_mesh(mesh)
t0, _ = gate_lib.verify_argmax(hn, w, impl="ref")
t1, _ = gate_lib.verify_argmax(hn, w, impl="ref", shard=shard)
assert np.array_equal(np.asarray(t0), np.asarray(t1))
i0, _ = gate_lib.verify_topk(hn, w, 6, impl="ref")
i1, _ = gate_lib.verify_topk(hn, w, 6, impl="ref", shard=shard)
assert np.array_equal(np.asarray(i0), np.asarray(i1))
print("SHARD-VERIFY-OK")
"""


def _run_subprocess(script: str) -> str:
    # pin cpu explicitly: with libtpu installed, an unset JAX_PLATFORMS
    # makes the child spin in TPU-client discovery instead of running
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", script],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    return r.stdout + r.stderr


def test_sharded_verify_unit_parity():
    """Sharded exit-gate verify (vocab-split partial (max, argmax) / top-k +
    merge) is bit-identical — tokens AND values — to the unsharded kernels
    for every impl × TP degree {2, 4} × vocab {512, 509, 500} (509/500 force
    the padded-shard masked path), including forced cross-shard ties."""
    out = _run_subprocess(_SHARDED_VERIFY)
    assert "SHARD-VERIFY-OK" in out, out


_SHARDED_DECODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax
import numpy as np
from repro.api import Engine
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.sharding import compat

DEGREES = %s


def build(vocab=None):
    run = get_config("llama2-7b").smoke()
    if vocab is not None:
        run = dataclasses.replace(
            run, model=dataclasses.replace(run.model, vocab_size=vocab))
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def decode(run, m, params, sw, strategy, cache, mesh, K=2):
    e = Engine.create(m, params, sw, strategy=strategy, mesh=mesh)
    s = e.new_session(batch=2, max_seq=48, cache=cache)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (2, 8), 0, run.model.vocab_size))
    toks = [[s.prefill_row(b, prompts[b], max_new_tokens=10)]
            for b in range(2)]
    while not s.all_done():
        res = s.step(num_ticks=K)
        for b in range(2):
            toks[b].extend(res.row_tokens(b))
    return toks


run, m, params, sw = build()
for deg in DEGREES:
    mesh = compat.make_mesh((1, deg), ("data", "model"),
                            devices=jax.devices()[:deg])
    for strategy in ("dense", "specee", "tree"):
        for cache in ("dense", "paged"):
            ref = decode(run, m, params, sw, strategy, cache, mesh=None)
            got = decode(run, m, params, sw, strategy, cache, mesh=mesh)
            assert got == ref, (deg, strategy, cache, got, ref)
            print("OK", deg, strategy, cache)
# non-divisible vocab (509, indivisible by any degree): padded-shard verify
# inside a real decode loop
run, m, params, sw = build(vocab=509)
mesh = compat.make_mesh((1, DEGREES[0]), ("data", "model"),
                        devices=jax.devices()[:DEGREES[0]])
ref = decode(run, m, params, sw, "specee", "paged", mesh=None)
got = decode(run, m, params, sw, "specee", "paged", mesh=mesh)
assert got == ref, (got, ref)
print("ODD-VOCAB-OK")
print("SHARD-DECODE-OK")
"""


@pytest.mark.parametrize("degrees", [(2,), (4,)])
def test_sharded_decode_token_parity(degrees):
    """End-to-end TP decode parity (acceptance): a sharded Engine emits
    token-identical megatick output vs the single-device reference across
    dense/specee/tree × dense/paged, plus a non-divisible-vocab (509)
    config, at model-parallel degree 2 and 4 on forced host devices."""
    out = _run_subprocess(_SHARDED_DECODE % repr(tuple(degrees)))
    assert "SHARD-DECODE-OK" in out, out
    assert "ODD-VOCAB-OK" in out, out


def test_pool_partition_dims():
    """Paged attention pools shard exactly one trailing dim ('model' on the
    KV-head dim of a 5-D pool when it divides), never the page-indexed
    leading dims; scale planes and non-divisible heads stay replicated."""
    from repro.core.paged import pool_partition_dims
    # (reps, NP+1, ps, KVH, hd): KVH=4 divides 2 -> sharded
    assert pool_partition_dims((2, 9, 16, 4, 32), 2) == \
        (None, None, None, "model", None)
    # KVH=3 does not divide 2 -> hd picks it up
    assert pool_partition_dims((2, 9, 16, 3, 32), 2) == \
        (None, None, None, None, "model")
    # neither divides -> fully replicated
    assert pool_partition_dims((2, 9, 16, 3, 31), 2) == \
        (None,) * 5
    # 4-D non-attention plane: cand dims are (ps, X) but cand >= 3 fails
    # for dim 2 -> only the last dim may shard
    assert pool_partition_dims((2, 9, 16, 8), 2) == \
        (None, None, None, "model")
    # unsharded mesh: all None
    assert pool_partition_dims((2, 9, 16, 4, 32), 1) == (None,) * 5


def test_paged_partition_specs_layout():
    """``PagedKVCache.partition_specs`` shards attention pool leaves on the
    KV-head dim and replicates the page table, lengths, and non-attention
    entries — every shard must resolve the same page indirection."""
    from repro.api.cache import make_cache_manager, CacheSpec
    run = get_config("llama2-7b").smoke()
    model = build_model(run)
    mgr = make_cache_manager(model, 2, 64,
                             CacheSpec.resolve("paged", run.serve))
    cache = mgr.empty_cache()

    class FakeMesh:
        shape = {"data": 1, "model": 2}
    specs = mgr.partition_specs(cache, FakeMesh())
    assert tuple(specs["page_table"]) == ()
    assert tuple(specs["len"]) == ()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs["segments"], is_leaf=lambda x: isinstance(x, P))
    sharded = [spec for _, spec in flat if "model" in tuple(spec)]
    assert sharded, "no pool leaf picked up the model axis"
    for _, spec in flat:
        dims = tuple(spec)
        # page-indexed leading dims (reps, pages, page_size) stay whole
        assert all(d is None for d in dims[:3]), dims


def test_hlo_collective_analyzer():
    from repro.launch.hlo_analysis import collective_totals
    txt = """
HloModule test
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), to_apply=%add
  ROOT %t = tuple(...)
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = bf16[4,8]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    r = collective_totals(txt, default_trip=99)
    # entry all-gather once (64 B) + loop all-reduce ×10 (32 B each)
    assert r["by_op"]["all-gather"] == 4 * 8 * 2
    assert r["by_op"]["all-reduce"] == 10 * 8 * 4
    assert r["unknown_trips"] == 0
