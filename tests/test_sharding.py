"""Sharding-policy tests: spec validity (divisibility-aware fallbacks) and an
end-to-end small-mesh compile of the launch path (subprocess, 4 CPU devices).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import build_model


def _mesh11():
    from repro.sharding.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,policy", [
    ("llama2-7b", "tp_dp"), ("command-r-plus-104b", "tp2d"),
    ("qwen3-moe-235b-a22b", "tp2d"), ("minicpm-2b", "fsdp_tp"),
    ("recurrentgemma-9b", "tp_dp"), ("mamba2-130m", "tp_dp"),
])
def test_param_specs_are_valid(arch, policy):
    """Every leaf gets a PartitionSpec whose sharded dims divide the mesh
    extent (checked against the REAL production shapes via eval_shape)."""
    from repro.sharding import param_specs
    run = get_config(arch)
    model = build_model(run)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # fake a 16x16 mesh purely for extent lookups
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    specs = param_specs(model, FakeMesh(), policy, shapes)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            ext = int(np.prod([FakeMesh.shape[a] for a in
                               (ax if isinstance(ax, tuple) else (ax,))]))
            assert dim % ext == 0, f"{arch}: {leaf.shape} vs {spec}"


def test_odd_vocab_falls_back_to_replicated():
    """minicpm's 122753 vocab divides nothing — embedding must not shard V."""
    from repro.sharding import param_specs
    run = get_config("minicpm-2b")
    model = build_model(run)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    specs = param_specs(model, FakeMesh(), "tp_dp", shapes)
    assert tuple(specs["embed"]["tok"])[0] is None


_SMALL_MESH_COMPILE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import build_model, ModelFlags
from repro.launch.specs import input_specs
from repro.launch.dryrun import step_fn_for
from repro.config import ShapeCell

from repro.sharding.compat import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
run = get_config("llama2-7b").smoke()
for cell, kind in [(ShapeCell("train_4k", "train", 32, 4), "train"),
                   (ShapeCell("decode_32k", "decode", 64, 4), "decode")]:
    model = build_model(run, ModelFlags(act_batch_axes="data",
                                        act_batch_extent=2))
    args, specs = input_specs(model, cell, mesh)
    fn = step_fn_for(model, run, cell, data_extent=2,
                     param_pspec=specs[0] if kind == "train" else None)
    in_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    print(kind, "COMPILED")
print("SMALL-MESH-OK")
"""


def test_small_mesh_launch_path_compiles():
    """The dryrun flow (specs -> shardings -> lower -> compile) on a 2x2 CPU
    mesh with the smoke config — CI coverage for the at-scale path."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SMALL_MESH_COMPILE],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    assert "SMALL-MESH-OK" in r.stdout, r.stdout + r.stderr


def test_hlo_collective_analyzer():
    from repro.launch.hlo_analysis import collective_totals
    txt = """
HloModule test
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), to_apply=%add
  ROOT %t = tuple(...)
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = bf16[4,8]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    r = collective_totals(txt, default_trip=99)
    # entry all-gather once (64 B) + loop all-reduce ×10 (32 B each)
    assert r["by_op"]["all-gather"] == 4 * 8 * 2
    assert r["by_op"]["all-reduce"] == 10 * 8 * 4
    assert r["unknown_trips"] == 0
