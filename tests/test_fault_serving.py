"""Fault-tolerant serving tests (DESIGN.md §7).

Acceptance property (this PR): under a deterministic fault schedule that
hits EVERY named injection site, the serving engine still completes every
request token-identical to a fault-free run, with zero page leak. Plus the
mechanism-level properties: checkpoint/restore resumes token-identically
after a (simulated) SIGTERM; pool-pressure eviction replays evicted
requests to the same outputs (greedy AND fixed-seed sampling, all three
strategies); dispatch retries exhaust into a structured ``ServingFault``;
``run_to_completion`` raises instead of silently returning while busy; a
slow megatick finish trips the watchdog onto the sync path.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import CacheSpec, DenseStrategy
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import build_model
from repro.runtime import faultinject
from repro.runtime.faultinject import FaultSchedule, InjectedFault
from repro.serving import (Backoff, Preempted, ServingEngine, ServingFault,
                           VictimPolicy)


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    # 3 slots so an oversubscribed 16-page pool (= 2 whole-row
    # reservations at page_size 16 / max_seq 128) leaves a slot free while
    # the pool is dry — the victim-eviction trigger
    run = dataclasses.replace(
        run, serve=dataclasses.replace(run.serve, max_batch=3))
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


TIGHT_POOL = CacheSpec(kind="paged", page_size=16, num_pages=16)


def _prompts(run, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(4, 12)))
            for _ in range(n)]


def _serve(model, params, sw, prompts, max_new=8, **kw):
    se = ServingEngine(model, params, sw, **kw)
    for p in prompts:
        se.submit(p, max_new_tokens=max_new)
    se.run_to_completion()
    se.close()
    return se


def _outputs(se):
    return {r.uid: list(r.output) for r in se.completed}


def _stats(se):
    return {r.uid: (list(r.exit_points), list(r.accept_lens))
            for r in se.completed}


def _assert_no_leak(se):
    mgr = se.session.cache_mgr
    if mgr.kind == "paged":
        assert mgr.free_pages == mgr.num_pages, \
            f"page leak: {mgr.free_pages}/{mgr.num_pages} free"


# ---------------- the acceptance property ----------------
def test_every_site_fires_and_tokens_match_fault_free(setup, tmp_path):
    """One run, one deterministic schedule hitting ALL five sites (plus
    real pool pressure from an oversubscribed pool): every request
    completes, token-identical to the fault-free reference, zero pages
    leaked, and the injector confirms each site actually fired."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    ref = _serve(m, params, sw, prompts, max_new=12, strategy="specee",
                 megatick=4)
    schedule = FaultSchedule.at(
        dispatch=[1], finish_timeout=[3], nan_logits=[5],
        pool_exhausted=range(2, 8), sigterm=[6])
    kw = dict(strategy="specee", megatick=4, cache=TIGHT_POOL,
              checkpoint_dir=str(tmp_path), backoff=Backoff(base_s=0.0),
              evict_patience=2, cooldown_ticks=2)

    fault_log = []                  # accumulated across restart incarnations
    with faultinject.injected(schedule) as inj:
        se = ServingEngine(m, params, sw, **kw)
        for p in prompts:
            se.submit(p, max_new_tokens=12)
        for _ in range(8):          # preemption/restart cycles, bounded
            try:
                se.run_to_completion()
                break
            except Preempted:
                fault_log.extend(se.fault_log)
                se.close()
                se = ServingEngine(m, params, sw, **kw)
                assert se.restore_checkpoint()
        else:
            pytest.fail("engine never ran to completion")
        fault_log.extend(se.fault_log)
        se.close()
        # every ENGINE-RECOVERABLE site on an unsharded engine; device_lost
        # needs a TP mesh to degrade onto and has its own acceptance suite
        # (tests/test_remesh.py, forced host devices)
        assert inj.fired_sites() == \
            frozenset(faultinject.SITES) - {"device_lost"}, \
            f"sites that fired: {sorted(inj.fired_sites())}"

    assert _outputs(se) == _outputs(ref)
    assert all(r.done for r in se.completed)
    assert len(se.completed) == len(prompts)
    _assert_no_leak(se)
    # the recovery paths actually ran (not just the sites firing)
    actions = {e.action for e in fault_log}
    assert "retry" in actions and "recover" in actions


# ---------------- eviction / recompute parity ----------------
@pytest.mark.parametrize("strategy", ["dense", "specee", "tree"])
def test_eviction_recompute_parity_greedy(setup, strategy):
    """A request evicted under pool pressure and requeued produces the same
    final token sequence (and exit/accept stats) as an uninterrupted run —
    for every decode strategy."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    ref = _serve(m, params, sw, prompts, max_new=16, strategy=strategy,
                 megatick=4)
    se = _serve(m, params, sw, prompts, max_new=16, strategy=strategy,
                megatick=4, cache=TIGHT_POOL, evict_patience=2)
    evicts = [e for e in se.fault_log if e.action == "evict"]
    assert evicts, "tight pool never drove an eviction"
    assert _outputs(se) == _outputs(ref)
    assert _stats(se) == _stats(ref)
    assert max(r.evictions for r in se.completed) >= 1
    _assert_no_leak(se)


def test_eviction_recompute_parity_sampled(setup):
    """Same property under fixed-seed SAMPLING: per-row position-keyed
    sample keys make an evicted row resample identical tokens on replay."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=3)
    strat = DenseStrategy(temperature=1.0)
    kw = dict(strategy=strat, megatick=4, prng_seed=7)
    ref = _serve(m, params, sw, prompts, max_new=16, **kw)
    se = _serve(m, params, sw, prompts, max_new=16, cache=TIGHT_POOL,
                evict_patience=2, **kw)
    assert [e for e in se.fault_log if e.action == "evict"]
    assert _outputs(se) == _outputs(ref)
    _assert_no_leak(se)


def test_eviction_protection_terminates(setup):
    """max_evictions protection: even when the pool holds only ONE row
    reservation (every admission starves the rest), requests stop being
    re-evicted after the cap and the engine still finishes everything."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    pool1 = CacheSpec(kind="paged", page_size=16, num_pages=8)
    ref = _serve(m, params, sw, prompts, max_new=10, strategy="specee",
                 megatick=2)
    se = _serve(m, params, sw, prompts, max_new=10, strategy="specee",
                megatick=2, cache=pool1, evict_patience=1,
                victim=VictimPolicy(max_evictions=2))
    assert _outputs(se) == _outputs(ref)
    assert max(r.evictions for r in se.completed) <= 2
    _assert_no_leak(se)


# ---------------- checkpoint / restore ----------------
def test_checkpoint_restore_token_parity(setup, tmp_path):
    """SIGTERM (simulated via the guard) mid-decode: drain + checkpoint +
    Preempted; a fresh engine restores and finishes token-identically."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    ref = _serve(m, params, sw, prompts, max_new=8, strategy="specee",
                 megatick=4)
    kw = dict(strategy="specee", megatick=4, checkpoint_dir=str(tmp_path))
    se = ServingEngine(m, params, sw, **kw)
    for p in prompts:
        se.submit(p, max_new_tokens=8)
    for _ in range(3):
        se.step()
    se.guard.requested = True       # what the real SIGTERM handler sets
    with pytest.raises(Preempted):
        se.step()
    se.close()

    se2 = ServingEngine(m, params, sw, **kw)
    assert se2.restore_checkpoint()
    se2.run_to_completion()
    se2.close()
    assert _outputs(se2) == _outputs(ref)
    assert _stats(se2) == _stats(ref)
    assert len(se2.completed) == len(prompts)
    _assert_no_leak(se2)


def test_restore_on_empty_dir_is_fresh_boot(setup, tmp_path):
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="specee",
                       checkpoint_dir=str(tmp_path / "empty"))
    assert se.restore_checkpoint() is False
    se.close()


def test_snapshot_requires_drained_pipeline(setup):
    """A snapshot straddling an unread async megatick would capture host
    mirrors that trail the device — the session refuses."""
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="specee", megatick=2,
                       async_ticks=True)
    se.submit(_prompts(run, n=1)[0], max_new_tokens=6)
    while not se.in_flight:
        se.step()
    with pytest.raises(AssertionError, match="outstanding megaticks"):
        se.session.snapshot()
    se.drain()
    state, meta = se.session.snapshot()     # drained: fine
    assert meta["strategy"] == "specee"
    se.close()


def test_scheduler_abort_active_requeues_at_front(setup):
    """Checkpoint drain aborts the in-flight chunked admission back to the
    queue FRONT — it keeps its turn, and no pages stay claimed."""
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="specee", prefill_chunk=4)
    rng = np.random.default_rng(9)
    # a live decode row is what throttles chunked admission to one chunk
    # per tick (an idle engine runs all chunks in a single tick)
    se.submit(rng.integers(0, run.model.vocab_size, 4), max_new_tokens=8)
    se.step()
    long_prompt = rng.integers(0, run.model.vocab_size, 20)
    req = se.submit(long_prompt, max_new_tokens=4)
    se.step()                       # one 4-token chunk of a 20-token prompt
    assert se.scheduler.admitting == [req.uid]
    free_before = se.session.cache_mgr.free_pages
    assert se.scheduler.abort_active() == req.uid
    assert se.scheduler.admitting == []
    assert se.scheduler.queued[0] == req.uid
    assert se.session.cache_mgr.free_pages == free_before
    se.run_to_completion()          # and it still completes after the abort
    assert req.done and len(req.output) == 4
    se.close()


# ---------------- injection sweep (one site at a time) ----------------
# device_lost is excluded: recovery is a REMESH, which needs a TP mesh over
# forced host devices — covered end-to-end in tests/test_remesh.py; the
# no-survivor (unsharded) behavior is pinned below.
@pytest.mark.parametrize(
    "site", [s for s in faultinject.SITES if s != "device_lost"])
def test_single_site_injection_recovers(setup, tmp_path, site):
    run, m, params, sw = setup
    prompts = _prompts(run)
    ref = _serve(m, params, sw, prompts, max_new=8, strategy="specee",
                 megatick=4)
    schedule = (FaultSchedule.at(pool_exhausted=range(8))
                if site == "pool_exhausted"
                else FaultSchedule.once(site, visit=1))
    kw = dict(strategy="specee", megatick=4, backoff=Backoff(base_s=0.0),
              cooldown_ticks=2)
    with faultinject.injected(schedule) as inj:
        if site == "sigterm":
            kw["checkpoint_dir"] = str(tmp_path)
            se = ServingEngine(m, params, sw, **kw)
            for p in prompts:
                se.submit(p, max_new_tokens=8)
            with pytest.raises(Preempted):
                se.run_to_completion()
            se.close()
            se = ServingEngine(m, params, sw, **kw)
            assert se.restore_checkpoint()
            se.run_to_completion()
            se.close()
        else:
            se = _serve(m, params, sw, prompts, max_new=8, **kw)
        assert site in inj.fired_sites()
    assert _outputs(se) == _outputs(ref)
    assert len(se.completed) == len(prompts)
    _assert_no_leak(se)
    if site in ("finish_timeout", "nan_logits"):
        assert any(e.action == "recover" and e.site == site
                   for e in se.fault_log)
        assert any(e.action == "evict" for e in se.fault_log)
    if site == "dispatch":
        assert any(e.action == "retry" and e.site == "dispatch"
                   for e in se.fault_log)


def test_dispatch_retries_exhaust_to_structured_fault(setup):
    """Every dispatch attempt failing (injected on all visits) burns the
    whole backoff schedule and surfaces ServingFault with the site, the
    attempt count, and the underlying InjectedFault as the cause."""
    run, m, params, sw = setup
    backoff = Backoff(base_s=0.0, max_attempts=3)
    with faultinject.injected(FaultSchedule.at(dispatch=range(100))):
        se = ServingEngine(m, params, sw, strategy="specee", megatick=2,
                           backoff=backoff)
        se.submit(_prompts(run, n=1)[0], max_new_tokens=4)
        with pytest.raises(ServingFault) as ei:
            se.run_to_completion()
        se.close()
    assert ei.value.site == "dispatch"
    assert ei.value.attempts == 3
    assert isinstance(ei.value.cause, InjectedFault)
    assert sum(1 for e in se.fault_log if e.action == "retry") == 2


def test_run_to_completion_raises_on_stall(setup):
    """max_ticks exhausted while still busy is a hang, not a success —
    run_to_completion must surface it (the historical silent return made
    wedged serving loops undiagnosable)."""
    run, m, params, sw = setup
    # a pool that never admits: the queue stays populated forever
    with faultinject.injected(FaultSchedule.at(pool_exhausted=range(10_000))):
        se = ServingEngine(m, params, sw, strategy="specee")
        se.submit(_prompts(run, n=1)[0], max_new_tokens=4)
        with pytest.raises(ServingFault) as ei:
            se.run_to_completion(max_ticks=20)
        se.close()
    assert ei.value.site == "stall"
    assert "queued=1" in str(ei.value)


def test_watchdog_slow_finish_falls_back_to_sync(setup):
    """A finish slower than watchdog_s keeps its (valid) results but parks
    the engine on the synchronous path for cooldown_ticks — and the run
    still matches the fault-free reference."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    ref = _serve(m, params, sw, prompts, max_new=8, strategy="specee",
                 megatick=4)
    se = _serve(m, params, sw, prompts, max_new=8, strategy="specee",
                megatick=4, watchdog_s=1e-9, cooldown_ticks=3)
    falls = [e for e in se.fault_log if e.action == "sync_fallback"]
    assert falls and falls[0].site == "watchdog"
    assert _outputs(se) == _outputs(ref)
    _assert_no_leak(se)


# ---------------- data-parallel replica pool (DESIGN.md §9) ----------------
def _pool_engines(m, params, sw, n, **kw):
    kw.setdefault("strategy", "specee")
    kw.setdefault("megatick", 2)
    return [ServingEngine(m, params, sw, **kw) for _ in range(n)]


def _pool_outputs(prs):
    return [list(pr.output) for pr in prs]


def _single_ref(m, params, sw, prompts, max_new=8):
    se = _serve(m, params, sw, prompts, max_new=max_new, strategy="specee",
                megatick=2)
    return [list(r.output)
            for r in sorted(se.completed, key=lambda r: r.uid)]


def test_replica_pool_token_parity(setup):
    """N independent replicas behind one queue emit exactly what one engine
    emits per request — data parallelism must not change tokens."""
    from repro.serving import ReplicaPool
    run, m, params, sw = setup
    prompts = _prompts(run, n=4, seed=21)
    ref = _single_ref(m, params, sw, prompts)
    pool = ReplicaPool(_pool_engines(m, params, sw, 2))
    prs = [pool.submit(p, max_new_tokens=8) for p in prompts]
    pool.run_to_completion()
    assert _pool_outputs(prs) == ref
    assert all(pr.migrations == 0 for pr in prs)
    pool.close()


@pytest.mark.parametrize("kill_tick", [1, 2, 3])
def test_replica_pool_kill_mid_flight_parity(setup, kill_tick):
    """Property (acceptance): killing a replica at any point mid-decode
    requeues its in-flight requests onto survivors, which complete them
    token-identical to an uninterrupted single-engine run — the already-
    emitted tokens run as VERIFIED replay on the survivor."""
    from repro.serving import ReplicaPool
    run, m, params, sw = setup
    prompts = _prompts(run, n=4, seed=22)
    ref = _single_ref(m, params, sw, prompts)
    pool = ReplicaPool(_pool_engines(m, params, sw, 2))
    prs = [pool.submit(p, max_new_tokens=8) for p in prompts]
    for _ in range(kill_tick):
        pool.step()
    victims = [i for i in pool.live_replicas()
               if any(pr.replica == i and not pr.done
                      for pr in pool.requests.values())]
    progress_at_kill = {}
    if victims:
        v = victims[0]
        for pr in pool.requests.values():
            if pr.replica == v and not pr.done and pr.handle is not None:
                progress_at_kill[pr.uid] = len(pr.handle.output)
        pool.kill_replica(v, reason="test_kill")
    pool.run_to_completion()
    assert _pool_outputs(prs) == ref
    if victims:
        migrated = [pr for pr in prs if pr.migrations]
        assert migrated, "kill evicted a replica but nothing migrated"
        assert any(e.action == "kill_replica" for e in pool.fault_log)
        for pr in migrated:
            h = pr.handle
            # the survivor replay-verified every token recorded pre-kill
            assert h is not None and h.replay_total >= \
                progress_at_kill.get(pr.uid, 0)
            assert h.replayed == h.replay_total
    pool.close()


def test_replica_pool_straggler_eviction(setup):
    """A replica whose step-time EWMA drifts above the fleet is evicted
    (never the last live one); its requests migrate and the run still
    matches the single-engine reference."""
    from repro.runtime.fault import StragglerMonitor
    from repro.serving import ReplicaPool
    run, m, params, sw = setup
    prompts = _prompts(run, n=4, seed=23)
    ref = _single_ref(m, params, sw, prompts)
    monitor = StragglerMonitor(min_samples=2)
    # seed the fleet: replicas 0/1 fast, replica 2 pathologically slow
    for _ in range(2):
        monitor.record(0, 0.01)
        monitor.record(1, 0.01)
        monitor.record(2, 50.0)
    pool = ReplicaPool(_pool_engines(m, params, sw, 3), monitor=monitor)
    prs = [pool.submit(p, max_new_tokens=8) for p in prompts]
    pool.run_to_completion()
    assert _pool_outputs(prs) == ref
    kills = [e for e in pool.fault_log if e.action == "kill_replica"]
    assert kills and kills[0].site == "straggler"
    assert not pool.alive[2] and pool.alive[0] and pool.alive[1]
    pool.close()


def test_replica_pool_last_replica_death_raises(setup):
    """Killing the only live replica has nowhere to migrate — it must raise
    a structured ServingFault, not strand the queue silently."""
    from repro.serving import ReplicaPool
    run, m, params, sw = setup
    pool = ReplicaPool(_pool_engines(m, params, sw, 1))
    pool.submit(_prompts(run, n=1)[0], max_new_tokens=4)
    pool.step()
    with pytest.raises(ServingFault) as ei:
        pool.kill_replica(0, reason="test_kill")
    assert ei.value.site == "replica_pool"
