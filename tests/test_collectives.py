"""Tests for ``repro.runtime.collectives`` on a real (forced-host-device)
mesh: the overlapped collective matmul must equal the plain matmul
bit-for-bit in fp32, and the int8 error-feedback all-reduce must track the
exact fp32 psum within the quantization bound per step while the feedback
keeps the ACCUMULATED sum from drifting.

Both primitives are shard_map bodies, so the tests run in a subprocess with
``--xla_force_host_platform_device_count`` set before jax initializes
(tests/conftest.py pins the main process to one device).
"""
import os
import subprocess
import sys


def _run(script: str) -> str:
    # pin cpu explicitly: with libtpu installed, an unset JAX_PLATFORMS
    # makes the child spin in TPU-client discovery instead of running
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", script],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    return r.stdout + r.stderr


_COLLECTIVE_MATMUL = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime import collectives as coll
from repro.sharding import compat

mesh = compat.make_mesh((4,), ("model",))
for rows, K, N in [(8, 16, 12), (4, 32, 32)]:
    x = jax.random.normal(jax.random.PRNGKey(0), (rows * 4, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    # x arrives row-sharded; w replicated; out replicated on every device
    # (each device assembles the full (rows*4, N) product via the ring, so
    # there is no replication certificate — check_rep must be off)
    fn = compat.shard_map_unchecked(
        lambda xs, ws: coll.collective_matmul_ag(xs, ws, "model"),
        mesh, in_specs=(P("model", None), P(None, None)),
        out_specs=P(None, None))
    got = np.asarray(fn(x, w))
    want = np.asarray(jnp.dot(x, w, preferred_element_type=jnp.float32))
    assert got.shape == want.shape, (got.shape, want.shape)
    assert np.array_equal(got, want), np.abs(got - want).max()
print("CMATMUL-OK")
"""


def test_collective_matmul_matches_plain_matmul():
    """Ring all-gather × GEMM ≡ plain X @ w, bit-identical in fp32 (each
    row block is one un-reassociated dot either way), rows in source-rank
    order, on a 4-device 'model' ring."""
    out = _run(_COLLECTIVE_MATMUL)
    assert "CMATMUL-OK" in out, out


_COMPRESSED_PSUM = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime import collectives as coll
from repro.sharding import compat

mesh = compat.make_mesh((4,), ("model",))
DEG, T, D = 4, 24, 64
fn = compat.shard_map(
    lambda xs, es: coll.compressed_psum(xs, "model", es),
    mesh, in_specs=(P("model", None), P("model", None)),
    out_specs=(P(None, None), P("model", None)))

key = jax.random.PRNGKey(3)
err = jnp.zeros((DEG, D), jnp.float32)
acc_q = np.zeros(D, np.float64)     # accumulated compressed reduction
acc_f = np.zeros(D, np.float64)     # accumulated exact fp32 reduction
max_amax = 0.0
for t in range(T):
    key, k = jax.random.split(key)
    x = jax.random.normal(k, (DEG, D), jnp.float32)
    # the shared scale comes from the PRE-quantization target x + err_in,
    # so capture amax before fn overwrites err with the new residual
    amax = float(np.abs(np.asarray(x) + np.asarray(err)).max())
    out, err = fn(x, err)
    out = np.asarray(out)[0]
    exact = np.asarray(jnp.sum(x, axis=0))
    max_amax = max(max_amax, amax)
    # vs plain sum(x) one step carries BOTH the fresh quantization error
    # (<= P*scale/2) and the fed-back incoming residual (<= P*scale_prev/2):
    # bound with the running-max scale. The feedback telescopes these away
    # in the accumulated sum below.
    step_bound = DEG * (max_amax / 127.0) + 1e-5
    assert np.abs(out - exact).max() <= step_bound, (
        t, np.abs(out - exact).max(), step_bound)
    acc_q += out
    acc_f += exact
# error feedback: the ACCUMULATED drift stays bounded by the single-step
# bound (residuals re-enter the next quantization instead of compounding),
# so T steps do NOT accumulate T times the error
final_bound = 2.0 * DEG * (max_amax / 127.0) + 1e-5
drift = np.abs(acc_q - acc_f).max()
assert drift <= final_bound, (drift, final_bound)
print("DRIFT", drift, "BOUND", final_bound)
print("CPSUM-OK")
"""


def test_compressed_psum_error_feedback_converges():
    """Int8 all-reduce with error feedback on a 4-device mesh: every step's
    reduction is within the quantization bound of the exact fp32 psum, and
    the accumulated sum over 24 steps drifts by O(one step's bound), not
    O(T) — the error-feedback convergence property."""
    out = _run(_COMPRESSED_PSUM)
    assert "CPSUM-OK" in out, out
