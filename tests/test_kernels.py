"""Pallas kernels vs. pure-jnp oracles (interpret mode on CPU) + hypothesis
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is a soft dev "
                    "dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.spec_head.ops import spec_head
from repro.kernels.spec_head.ref import spec_head_ref
from repro.kernels.predictor_mlp.predictor_mlp import predictor_mlp_fused
from repro.kernels.predictor_mlp.ops import predictor_mlp
from repro.kernels.predictor_mlp.ref import predictor_mlp_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.ops import decode_attention_raw
from repro.kernels.decode_attention.ref import decode_attention_ref

SETTINGS = dict(max_examples=8, deadline=None)


# ---------------- spec_head ----------------
@pytest.mark.parametrize("B,D,V,k", [(4, 256, 512, 4), (1, 128, 32, 4),
                                     (7, 384, 1001, 5)])
def test_spec_head_allclose(B, D, V, k):
    hn = jax.random.normal(jax.random.PRNGKey(0), (B, D))
    W = jax.random.normal(jax.random.PRNGKey(1), (D, V)) * 0.05
    ids = jax.random.randint(jax.random.PRNGKey(2), (B, k), 0, V)
    lg, pr = spec_head(hn, W, ids)
    lgr, prr = spec_head_ref(hn, W, ids)
    np.testing.assert_allclose(lg, lgr, atol=1e-5)
    np.testing.assert_allclose(pr, prr, atol=1e-6)


@settings(**SETTINGS)
@given(B=st.integers(1, 6), D=st.sampled_from([128, 256, 320]),
       V=st.integers(16, 600), k=st.integers(2, 6),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_spec_head_hypothesis(B, D, V, k, dtype):
    hn = jax.random.normal(jax.random.PRNGKey(3), (B, D), dtype)
    W = (jax.random.normal(jax.random.PRNGKey(4), (D, V)) * 0.05).astype(dtype)
    ids = jax.random.randint(jax.random.PRNGKey(5), (B, k), 0, V)
    lg, _ = spec_head(hn, W, ids)
    lgr, _ = spec_head_ref(hn, W, ids)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(lg, lgr, atol=tol, rtol=tol)


# ---------------- predictor_mlp ----------------
def test_predictor_mlp_allclose():
    x = jax.random.normal(jax.random.PRNGKey(0), (37, 12))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (12, 64)) * 0.3
    b1 = jax.random.normal(jax.random.PRNGKey(2), (64,)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(3), (64, 1)) * 0.3
    b2 = jnp.zeros((1,))
    got = predictor_mlp_fused(x, w1, b1, w2, b2)
    ref = predictor_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_predictor_mlp_matches_core_predictor():
    """Kernel output == repro.core.predictor.apply_predictor (2-layer case)."""
    from repro.config import SpecEEConfig
    from repro.core import predictor as pred_lib
    spec = SpecEEConfig(predictor_hidden=64)
    p = pred_lib.init_predictor(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (9, spec.feature_dim()))
    ref = pred_lib.apply_predictor(p, x)
    got = predictor_mlp(x, p)
    np.testing.assert_allclose(got, ref, atol=1e-6)


@settings(**SETTINGS)
@given(B=st.integers(1, 300), F=st.sampled_from([12, 15]),
       H=st.sampled_from([32, 512]))
def test_predictor_mlp_hypothesis(B, F, H):
    x = jax.random.normal(jax.random.PRNGKey(4), (B, F))
    w1 = jax.random.normal(jax.random.PRNGKey(5), (F, H)) * 0.2
    b1 = jnp.zeros((H,))
    w2 = jax.random.normal(jax.random.PRNGKey(6), (H, 1)) * 0.2
    b2 = jnp.ones((1,)) * 0.1
    np.testing.assert_allclose(predictor_mlp_fused(x, w1, b1, w2, b2),
                               predictor_mlp_ref(x, w1, b1, w2, b2),
                               atol=1e-6)


# ---------------- flash attention ----------------
@pytest.mark.parametrize("B,S,H,KVH,hd,causal,window", [
    (2, 64, 4, 2, 32, True, None),
    (1, 128, 4, 1, 16, True, 32),
    (2, 32, 2, 2, 64, False, None),
    (1, 96, 8, 4, 32, True, None),
])
def test_flash_attention_allclose(B, S, H, KVH, hd, causal, window):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, hd))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, ref, atol=2e-5)


@settings(**SETTINGS)
@given(S=st.sampled_from([32, 48, 64]), H=st.sampled_from([2, 4]),
       rep=st.sampled_from([1, 2]), hd=st.sampled_from([16, 32]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_flash_attention_hypothesis(S, H, rep, hd, dtype):
    KVH = H // rep if H % rep == 0 else H
    q = jax.random.normal(jax.random.PRNGKey(3), (1, S, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (1, S, KVH, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (1, S, KVH, hd), dtype)
    got = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got.astype(np.float32),
                               ref.astype(np.float32), atol=tol, rtol=tol)


# ---------------- decode attention ----------------
@pytest.mark.parametrize("B,S,H,KVH,hd,window", [
    (2, 128, 8, 2, 32, None),
    (1, 256, 4, 1, 64, None),
    (2, 64, 4, 4, 32, 16),
])
def test_decode_attention_allclose(B, S, H, KVH, hd, window):
    clen = jnp.asarray(np.random.default_rng(0).integers(1, S, B), jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, hd))
    got = decode_attention_raw(q, k, v, clen, window=window, block_k=32)
    ref = decode_attention_ref(q, k, v, clen, window=window)
    np.testing.assert_allclose(got, ref, atol=2e-5)


@settings(**SETTINGS)
@given(B=st.integers(1, 4), S=st.sampled_from([64, 128]),
       KVH=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2, 4]),
       clen_frac=st.floats(0.1, 1.0))
def test_decode_attention_hypothesis(B, S, KVH, rep, clen_frac):
    H, hd = KVH * rep, 32
    clen = max(1, int(S * clen_frac))
    q = jax.random.normal(jax.random.PRNGKey(6), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(7), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (B, S, KVH, hd))
    got = decode_attention_raw(q, k, v, clen, block_k=32)
    ref = decode_attention_ref(q, k, v, clen)
    np.testing.assert_allclose(got, ref, atol=2e-5)


def test_decode_attention_matches_model_path():
    """Kernel == the model's attend_decode on a real cache layout."""
    from repro.configs import get_config
    from repro.models import attention as attn
    run = get_config("llama2-70b").smoke()  # GQA smoke
    cfg = run.model
    B, S = 2, 64
    hd = cfg.resolved_head_dim()
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, cfg.num_heads, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.num_kv_heads, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.num_kv_heads, hd))
    clen = jnp.array([40, 17], jnp.int32)
    ref = attn.attend_decode(cfg, q, k, v, clen)
    got = decode_attention_raw(q, k, v, clen, block_k=16)
    np.testing.assert_allclose(got, ref, atol=2e-5)


# ---------------- ssd_chunk ----------------
@pytest.mark.parametrize("B,c,nh,hd,ds", [(2, 32, 4, 32, 16),
                                          (1, 64, 24, 64, 128)])
def test_ssd_chunk_allclose(B, c, nh, hd, ds):
    from repro.kernels.ssd_chunk.ops import ssd_chunk
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    xdt = jax.random.normal(k[0], (B, c, nh, hd))
    cum = -jnp.cumsum(jax.random.uniform(k[1], (B, c, nh)), axis=1)
    Bc = jax.random.normal(k[2], (B, c, ds))
    Cc = jax.random.normal(k[3], (B, c, ds))
    np.testing.assert_allclose(ssd_chunk(xdt, cum, Bc, Cc),
                               ssd_chunk_ref(xdt, cum, Bc, Cc), atol=1e-4)


@settings(**SETTINGS)
@given(B=st.integers(1, 3), c=st.sampled_from([16, 32]),
       nh=st.sampled_from([2, 8]), hd=st.sampled_from([16, 64]),
       ds=st.sampled_from([16, 64]))
def test_ssd_chunk_hypothesis(B, c, nh, hd, ds):
    from repro.kernels.ssd_chunk.ops import ssd_chunk
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    k = jax.random.split(jax.random.PRNGKey(1), 4)
    xdt = jax.random.normal(k[0], (B, c, nh, hd))
    cum = -jnp.cumsum(jax.random.uniform(k[1], (B, c, nh)), axis=1)
    Bc = jax.random.normal(k[2], (B, c, ds))
    Cc = jax.random.normal(k[3], (B, c, ds))
    np.testing.assert_allclose(ssd_chunk(xdt, cum, Bc, Cc),
                               ssd_chunk_ref(xdt, cum, Bc, Cc), atol=1e-4)
