"""Weight-only quant subsystem (repro.quant) tests: packing round-trips,
no-mutation guarantees, fused-kernel parity against the dequantized
reference, and the acceptance-criterion token parity — a quantized engine
must emit exactly what a plain engine decoding the dequantized weights
emits, across dense/specee strategies × dense/paged caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.api import Engine
from repro.configs import get_config
from repro.core import engine as eng
from repro.kernels.exit_gate import ops as gate_ops
from repro.kernels.exit_gate import ref as gate_ref
from repro.kernels.predictor_mlp import ops as pm_ops
from repro.kernels.spec_head import ops as sh_ops
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _drain(session, first_res):
    toks = [first_res.row_tokens(b) for b in range(first_res.batch)]
    while not session.all_done():
        res = session.step()
        for b in range(res.batch):
            toks[b].extend(res.row_tokens(b))
    return toks


def _prompts(run, B=2, T=8, seed=4):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, T), 0,
                              run.model.vocab_size)


# ---------------- packing / QTensor layout ----------------
def test_int4_pack_unpack_round_trip():
    codes = jax.random.randint(jax.random.PRNGKey(0), (6, 64, 16), -7, 8)
    packed = quant.pack_int4(codes)
    assert packed.dtype == jnp.int8
    assert packed.shape == (6, 32, 16)
    lo, hi = quant.unpack_int4(packed)
    round_trip = jnp.concatenate([lo, hi], axis=-2)
    np.testing.assert_array_equal(np.asarray(round_trip), np.asarray(codes))


def test_int4_pack_rejects_odd_rows():
    with pytest.raises(ValueError, match="even row count"):
        quant.pack_int4(jnp.zeros((5, 3), jnp.int32))


@pytest.mark.parametrize("bits,qmax", [(8, 127), (4, 7)])
def test_quantize_tensor_error_bound(bits, qmax):
    """Symmetric round-to-nearest: |W - dq(W)| <= scale/2 per column."""
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 48))
    qt = quant.quantize_tensor(w, bits)
    assert qt.bits == bits
    assert qt.shape == w.shape
    err = np.abs(np.asarray(qt.dequantize() - w))
    bound = np.asarray(qt.scale)[None, :] / 2 + 1e-6
    assert (err <= bound).all()


def test_int4_odd_rows_falls_back_to_int8():
    qt = quant.quantize_tensor(jnp.ones((63, 8)), 4)
    assert qt.bits == 8
    assert qt.q.shape == (63, 8)


def test_take_columns_commutes_with_dequant():
    """dequant(gather) == gather(dequant) exactly (per-column scales)."""
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 100))
    ids = jnp.asarray([[3, 97, 0], [50, 50, 11]], jnp.int32)
    for bits in (8, 4):
        qt = quant.quantize_tensor(w, bits)
        got = quant.take_columns(qt, ids)
        want = jnp.take(qt.dequantize(), ids, axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qtensor_is_pytree():
    qt = quant.quantize_tensor(jnp.eye(8), 8)
    doubled = jax.tree_util.tree_map(lambda x: x, qt)
    assert isinstance(doubled, quant.QTensor) and doubled.bits == 8
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), qt, qt)
    sliced = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, 1, 0, False), stacked)
    np.testing.assert_array_equal(np.asarray(sliced.dequantize()),
                                  np.asarray(qt.dequantize()))


# ---------------- QuantSpec + params conversion ----------------
def test_quant_spec_resolve():
    assert quant.QuantSpec.resolve(None) is None
    assert quant.QuantSpec.resolve("int8").bits == 8
    assert quant.QuantSpec.resolve("int4").bits == 4
    assert quant.QuantSpec.resolve(4).bits == 4
    spec = quant.QuantSpec(bits=8, proj=False)
    assert quant.QuantSpec.resolve(spec) is spec
    with pytest.raises(ValueError):
        quant.QuantSpec.resolve("int2")
    with pytest.raises(ValueError):
        quant.QuantSpec(bits=16)


def test_quantize_params_never_mutates_originals(setup):
    """The parallel pytree must leave params and sw bit-untouched."""
    run, m, params, sw = setup
    before_p = [np.asarray(x).copy()
                for x in jax.tree_util.tree_leaves(params)]
    before_s = [np.asarray(x).copy() for x in jax.tree_util.tree_leaves(sw)]
    for spec in ("int8", "int4"):
        qw = quant.quantize_params(params, sw, spec)
        assert set(qw) == {"lm_head", "predictors", "proj"}
        assert qw["lm_head"] is not None and qw["proj"] is not None
        # building the dequantized reference must not write back either
        quant.dequantized_reference(params, sw, qw)
    for a, b in zip(before_p, jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    for a, b in zip(before_s, jax.tree_util.tree_leaves(sw)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_quantize_params_selection_flags(setup):
    run, m, params, sw = setup
    qw = quant.quantize_params(
        params, sw, quant.QuantSpec(bits=8, lm_head=False, proj=False))
    assert qw["lm_head"] is None and qw["proj"] is None
    assert qw["predictors"] is not None


# ---------------- fused kernel parity vs dequantized oracle ----------------
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_verify_argmax_quantized_parity(bits, impl):
    hn = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(6), (64, 500)) * 0.1
    qt = quant.quantize_tensor(w, bits)
    ref_tok, ref_val = gate_ref.verify_argmax_ref(hn, qt.dequantize())
    tok, val = gate_ops.verify_argmax(hn, qt, impl=impl, block_v=128)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(ref_tok))
    np.testing.assert_allclose(np.asarray(val), np.asarray(ref_val),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_verify_topk_quantized_parity(bits, impl):
    hn = jax.random.normal(jax.random.PRNGKey(7), (3, 64))
    w = jax.random.normal(jax.random.PRNGKey(8), (64, 500)) * 0.1
    qt = quant.quantize_tensor(w, bits)
    ref_ids, ref_vals = gate_ref.verify_topk_ref(hn, qt.dequantize(), 4)
    ids, vals = gate_ops.verify_topk(hn, qt, 4, impl=impl, block_v=128)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref_vals),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [8, 4])
def test_spec_head_quantized_parity(bits):
    hn = jax.random.normal(jax.random.PRNGKey(9), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(10), (64, 500)) * 0.1
    ids = jax.random.randint(jax.random.PRNGKey(11), (4, 3), 0, 500)
    qt = quant.quantize_tensor(w, bits)
    ref_logits, ref_probs = sh_ops.spec_head(hn, qt.dequantize(), ids)
    logits, probs = sh_ops.spec_head(hn, qt, ids)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs), np.asarray(ref_probs),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [8, 4])
def test_predictor_mlp_quantized_parity(bits):
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 9))
    p = {"layers": [
        {"w": jax.random.normal(jax.random.PRNGKey(13), (9, 32)) * 0.3,
         "b": jnp.zeros((32,))},
        {"w": jax.random.normal(jax.random.PRNGKey(14), (32, 1)) * 0.3,
         "b": jnp.zeros((1,))}]}
    pq = {"layers": [{"w": quant.quantize_tensor(l["w"], bits), "b": l["b"]}
                     for l in p["layers"]]}
    pref = {"layers": [{"w": l["w"].dequantize(), "b": l["b"]}
                       for l in pq["layers"]]}
    want = pm_ops.predictor_mlp(x, pref)
    got = pm_ops.predictor_mlp(x, pq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------- engine-level token parity (acceptance criterion) -------
@pytest.mark.parametrize("strategy", ["dense", "specee"])
@pytest.mark.parametrize("cache", ["dense", "paged"])
@pytest.mark.parametrize("spec", ["int8", "int4"])
def test_engine_quant_token_parity(setup, strategy, cache, spec):
    """A quantized engine on (params, qw) decodes token-identically to a
    plain engine on the dequantized weights — across strategies and cache
    layouts. This is the subsystem's end-to-end correctness oracle: any
    drift between the fused int kernels and the fp reference shows up as a
    token mismatch here."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=21)
    e_q = Engine.create(m, params, sw=sw, strategy=strategy, quant=spec)
    pref, swref = quant.dequantized_reference(params, sw, e_q.qw)
    e_ref = Engine.create(m, pref, sw=swref, strategy=strategy)
    outs = {}
    for name, e in (("quant", e_q), ("ref", e_ref)):
        s = e.new_session(cache=cache)
        res = s.prefill(prompts, max_new_tokens=6)
        outs[name] = _drain(s, res)
    assert outs["quant"] == outs["ref"]
    assert all(len(t) == 6 for t in outs["quant"])


def test_engine_quant_leaves_params_untouched(setup):
    run, m, params, sw = setup
    before = [np.asarray(x).copy()
              for x in jax.tree_util.tree_leaves(params)]
    e = Engine.create(m, params, sw=sw, strategy="specee", quant="int4")
    s = e.new_session()
    res = s.prefill(_prompts(run, seed=22), max_new_tokens=3)
    _drain(s, res)
    for a, b in zip(before, jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_engine_quant_none_has_no_qw(setup):
    run, m, params, sw = setup
    e = Engine.create(m, params, sw=sw, strategy="specee")
    assert e.qw is None and e.quant_spec is None
