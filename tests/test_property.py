"""Hypothesis property tests over system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is a soft dev "
                    "dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import SpecEEConfig
from repro.core import scheduler as sched_lib
from repro.core.tree import TreeSpec
from repro.models.model import segments_of

SETTINGS = dict(max_examples=25, deadline=None)

KINDS = ["attention", "rglru", "ssd", "local_attention"]


@settings(**SETTINGS)
@given(st.lists(st.sampled_from(KINDS), min_size=1, max_size=40))
def test_segments_recompose(blocks):
    """segments_of is a lossless decomposition: units×reps re-concatenate to
    the original pattern, and units are non-empty."""
    segs = segments_of(blocks)
    flat = [k for unit, reps in segs for _ in range(reps) for k in unit]
    assert flat == blocks
    assert all(reps >= 1 and len(unit) >= 1 for unit, reps in segs)


@settings(**SETTINGS)
@given(E=st.integers(4, 64), window=st.integers(1, 8),
       radius=st.integers(0, 4),
       exits=st.lists(st.integers(0, 63), min_size=0, max_size=12),
       frac=st.floats(0.05, 1.0))
def test_scheduler_invariants(E, window, radius, exits, frac):
    """Active set ⊇ offline top-set ∪ (±radius of queued exits); bounded by E."""
    spec = SpecEEConfig(online_window=window, online_radius=radius,
                        offline_top_frac=frac)
    counts = jnp.asarray(np.random.default_rng(0).random(E), jnp.float32)
    offline = sched_lib.offline_mask_from_counts(counts, spec)
    assert int(offline.sum()) == max(1, round(frac * E))
    stt = sched_lib.init_state(1, spec)
    for e in exits:
        stt = sched_lib.update(stt, jnp.array([min(e, E - 1)]))
    am = sched_lib.active_mask(stt, offline, spec, E)[0]
    # superset of offline
    assert bool(jnp.all(am | ~offline))
    # superset of the last `window` exits' neighbourhoods
    recent = [min(e, E - 1) for e in exits][-window:]
    for e in recent:
        for j in range(max(0, e - radius), min(E, e + radius + 1)):
            assert bool(am[j]), (e, j)
    # queue length bounded
    assert int((stt["queue"][0] >= 0).sum()) <= window


@settings(**SETTINGS)
@given(depth=st.integers(1, 3), branch=st.integers(2, 4))
def test_tree_invariants(depth, branch):
    t = TreeSpec(depth=depth, branch=branch)
    # node count and path count
    assert t.num_nodes == sum(branch ** l for l in range(depth + 1))
    assert t.path_nodes.shape == (branch ** depth, depth + 1)
    # levels consistent with parents
    for n in range(1, t.num_nodes):
        assert t.levels[n] == t.levels[t.parents[n]] + 1
    # ancestor mask is a partial order (transitive, antisymmetric off-diag)
    am = t.ancestor_mask
    assert (am @ am <= am * t.num_nodes).all()  # transitivity (bool algebra)
    assert not (am & am.T & ~np.eye(t.num_nodes, dtype=bool)).any()


@settings(**SETTINGS)
@given(B=st.integers(1, 4), N=st.integers(2, 8), k=st.integers(2, 5),
       seed=st.integers(0, 99))
def test_hyper_token_merge_cannikin(B, N, k, seed):
    """Merged path features are elementwise ≤ every member node's features
    (Cannikin: the weakest node gates the path)."""
    from repro.core import features as feat_lib
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.standard_normal((B, N, 3 * k)), jnp.float32)
    probs = jnp.asarray(rng.random((B, N, k)), jnp.float32)
    depth = min(3, N)
    path = jnp.asarray(rng.choice(N, size=(1, depth), replace=False),
                       jnp.int32)
    pf, pp = feat_lib.merge_path_features(feats, probs, path,
                                          jnp.array([depth]))
    for d in range(depth):
        node = int(path[0, d])
        assert bool(jnp.all(pf[:, 0] <= feats[:, node] + 1e-6))
        assert bool(jnp.all(pp[:, 0] <= probs[:, node] + 1e-6))


@settings(**SETTINGS)
@given(n=st.integers(1, 512), scale=st.floats(0.01, 100.0),
       seed=st.integers(0, 99))
def test_int8_quantization_error_bound(n, scale, seed):
    from repro.runtime.collectives import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = quantize_int8(x)
    # error ≤ half a quantization step, scale = amax/127
    assert float(jnp.max(jnp.abs(dequantize_int8(q, s) - x))) <= float(s) * 0.51


@settings(**SETTINGS)
@given(alive=st.integers(1, 600), tp=st.sampled_from([4, 8, 16]),
       pods=st.sampled_from([1, 2, 4]))
def test_remesh_plan_sound(alive, tp, pods):
    from repro.runtime.fault import plan_remesh
    plan = plan_remesh(alive, tp, pods)
    if plan is None:
        # truly unrecoverable: survivors spread evenly over pods leave no
        # pod holding even ONE whole TP group (a group can't straddle the
        # pod boundary) — the largest pod has ceil(alive/pods) devices
        assert -(-alive // pods) < tp
    else:
        assert np.prod(plan) <= alive          # never over-subscribes
        assert plan[-1] == tp                  # TP degree preserved
        assert all(p >= 1 for p in plan)
