"""Paged KV CacheManager + chunked-prefill scheduler tests.

Property under test (PR 3 acceptance): paged-cache decode is bit-identical
to the dense-cache reference for the same prompts under dense, AR-SpecEE,
and tree strategies (including ``kv_quant``); per-row compaction frees a
retired row's span/pages; chunked prefill never stalls live decode rows for
more than one chunk budget per tick.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CacheSpec, DenseKVCache, Engine, PagedKVCache
from repro.config import ServeConfig
from repro.configs import get_config
from repro.core import engine as eng
from repro.models.model import ModelFlags, build_model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _drain(session, first_res):
    toks = [first_res.row_tokens(b) for b in range(first_res.batch)]
    while not session.all_done():
        res = session.step()
        for b in range(res.batch):
            toks[b].extend(res.row_tokens(b))
    return toks


def _prompts(run, n=3, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(lo, hi)))
            for _ in range(n)]


def _serve(model, params, sw, prompts, max_new=5, **kw):
    se = ServingEngine(model, params, sw, **kw)
    reqs = [se.submit(p, max_new_tokens=max_new) for p in prompts]
    se.run_to_completion()
    return se, [r.output for r in reqs]


# ---------------- bit-identity: paged vs dense ----------------
@pytest.mark.parametrize("strategy", ["dense", "specee", "tree"])
def test_whole_batch_paged_matches_dense(setup, strategy):
    """Session-level property: the paged layout emits bit-identical tokens
    to the dense reference for every strategy (whole-batch prefill)."""
    run, m, params, sw = setup
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                                 run.model.vocab_size)
    outs = {}
    for cache in ("dense", "paged"):
        session = Engine.create(m, params, sw, strategy=strategy) \
            .new_session(cache=cache)
        outs[cache] = _drain(session,
                             session.prefill(prompts, max_new_tokens=6))
    assert outs["dense"] == outs["paged"]
    assert isinstance(
        Engine.create(m, params, sw, strategy=strategy)
        .new_session(batch=2, cache="paged").cache_mgr, PagedKVCache)


@pytest.mark.parametrize("strategy", ["specee", "tree"])
def test_serving_paged_matches_dense(setup, strategy):
    """Continuous-batching parity: slot admission + retirement through the
    paged manager reproduce dense serving token-for-token."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=1)
    outs = {}
    for cache in ("dense", "paged"):
        _, outs[cache] = _serve(m, params, sw, prompts, strategy=strategy,
                                cache=cache)
    assert outs["dense"] == outs["paged"]


def test_serving_paged_matches_dense_kv_quant(setup):
    """The int8 KV path reads/writes through the page table bit-identically
    (dequant∘gather == gather∘dequant)."""
    run, m, params, sw = setup
    mq = build_model(run, ModelFlags(kv_quant=True))
    prompts = _prompts(run, seed=2)
    outs = {}
    for cache in ("dense", "paged"):
        _, outs[cache] = _serve(mq, params, sw, prompts, strategy="specee",
                                cache=cache)
    assert outs["dense"] == outs["paged"]
    assert len(outs["paged"][0]) == 5


def test_tree_rejects_kv_quant(setup):
    """Tree × kv_quant is unsupported (scratch writes are full-precision);
    the strategy rejects it with a clear error instead of a tree_map crash
    inside the first step. The MESSAGE is pinned: it names the cause and
    both escape hatches, and DESIGN.md §4's support matrix cites it — a
    reworded error must update the matrix in the same change."""
    run, m, params, sw = setup
    mq = build_model(run, ModelFlags(kv_quant=True))
    with pytest.raises(ValueError) as ei:
        Engine.create(mq, params, sw, strategy="tree")
    assert str(ei.value) == (
        "tree strategy does not support kv_quant: tree scratch writes are "
        "full-precision (the node K/V is re-read within the same step, "
        "where int8 round-tripping would corrupt verification); decode "
        "with the AR engine instead (DESIGN.md §4)")


def test_paged_hybrid_arch(setup):
    """Mixed stacks: attention entries paged, recurrent entries dense —
    the manager pages only what has a sequence axis."""
    run = get_config("recurrentgemma-9b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0,
                                 run.model.vocab_size)
    outs = {}
    for cache in ("dense", "paged"):
        session = Engine.create(m, params, sw, strategy="specee") \
            .new_session(cache=cache)
        outs[cache] = _drain(session,
                             session.prefill(prompts, max_new_tokens=4))
    assert outs["dense"] == outs["paged"]


# ---------------- compaction ----------------
def test_retirement_compacts_row_span(setup):
    """A finished (long-idle) slot's attention span collapses at retirement
    and its pages return to the free list; the slot readmits cleanly."""
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="specee", cache="paged")
    mgr = se.session.cache_mgr
    short, lng = _prompts(run, n=2, seed=3)
    r_short = se.submit(short, max_new_tokens=2)
    r_long = se.submit(lng, max_new_tokens=12)
    free0 = mgr.free_pages
    while not r_short.done:
        se.step()
    # the short row retired: span zero, pages back; the long row still pays
    spans = [se.session.row_span(r) for r in range(se.B)]
    assert 0 in spans and max(spans) > 0
    assert mgr.free_pages >= mgr.num_pages - mgr.pages_per_row, \
        "retired row's pages did not return to the free list"
    se.run_to_completion()
    assert r_long.done and len(r_long.output) == 12
    assert mgr.free_pages == mgr.num_pages          # full reclamation
    assert all(se.session.row_span(r) == 0 for r in range(se.B))
    # readmission into compacted slots
    r2 = se.submit(short, max_new_tokens=3)
    se.run_to_completion()
    assert r2.done and len(r2.output) == 3


def test_admission_control_oversubscribed_pool(setup):
    """A pool with room for one row defers the second request until the
    first retires (free-page admission gate) — nothing overcommits."""
    run, m, params, sw = setup
    spec = CacheSpec(kind="paged", page_size=16,
                     num_pages=-(-run.serve.max_seq_len // 16))  # one row
    se = ServingEngine(m, params, sw, strategy="specee", cache=spec)
    a, b = _prompts(run, n=2, seed=4)
    ra, rb = se.submit(a, max_new_tokens=3), se.submit(b, max_new_tokens=3)
    se.step()
    assert len(se.pending) == 1         # b deferred: no free row reservation
    done = se.run_to_completion()
    assert len(done) == 2 and ra.done and rb.done
    assert len(ra.output) == 3 and len(rb.output) == 3


# ---------------- chunked prefill ----------------
def test_chunked_matches_blocking_admission(setup):
    """Chunked admission (chunk=4) and blocking admission emit the same
    tokens — the chunk boundary is invisible downstream."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=5, lo=6, hi=12)
    outs = {}
    for chunk in (4, 0):
        _, outs[chunk] = _serve(m, params, sw, prompts, strategy="specee",
                                cache="paged", prefill_chunk=chunk)
    assert outs[4] == outs[0]


def test_chunked_prefill_interleaves_with_decode(setup):
    """The Sarathi invariant: while decode rows are live, a tick runs at
    most one chunk budget of prefill — a long admission spans many ticks and
    the live row keeps emitting throughout."""
    run, m, params, sw = setup
    chunk = 4
    se = ServingEngine(m, params, sw, strategy="specee", cache="paged",
                       prefill_chunk=chunk)
    short = _prompts(run, n=1, seed=6)[0]
    long_prompt = np.asarray(_prompts(run, n=1, seed=7, lo=20, hi=21)[0])
    r_short = se.submit(short, max_new_tokens=16)
    se.step()                                   # admit + first decode tick
    r_long = se.submit(long_prompt, max_new_tokens=2)
    progress = []
    ticks_during_admission = 0
    while not r_long.done:
        emitted_before = len(r_short.output)
        se.step()
        if se.scheduler.last_tick_tokens:
            ticks_during_admission += 1
            assert se.scheduler.last_tick_tokens <= chunk
            # the live row kept decoding during the admission tick
            if not r_short.done:
                progress.append(len(r_short.output) - emitted_before)
    assert ticks_during_admission >= len(long_prompt) // chunk
    assert any(p > 0 for p in progress), \
        "live decode stalled during chunked admission"
    se.run_to_completion()
    assert len(r_short.output) == 16 and len(r_long.output) == 2


def test_chunked_matches_blocking_admission_kv_quant(setup):
    """kv_quant × chunked prefill: ``attend_extend`` claims kv_quant
    awareness, but only whole-batch admission exercised it — chunked
    admission must quantize each chunk's K/V identically to the blocking
    path (same tokens out, both cache layouts)."""
    run, m, params, sw = setup
    mq = build_model(run, ModelFlags(kv_quant=True))
    prompts = _prompts(run, seed=11, lo=6, hi=12)
    outs = {}
    for cache in ("dense", "paged"):
        for chunk in (4, 0):
            _, outs[(cache, chunk)] = _serve(
                mq, params, sw, prompts, strategy="specee", cache=cache,
                prefill_chunk=chunk)
    assert outs[("dense", 4)] == outs[("dense", 0)]
    assert outs[("paged", 4)] == outs[("paged", 0)]
    assert outs[("paged", 0)] == outs[("dense", 0)]


def test_chunked_prefill_dense_cache_too(setup):
    """Chunked admission is cache-layout-independent (works over the dense
    manager as well)."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=8, lo=6, hi=12)
    outs = {}
    for cache in ("dense", "paged"):
        _, outs[cache] = _serve(m, params, sw, prompts, strategy="specee",
                                cache=cache, prefill_chunk=4)
    assert outs["dense"] == outs["paged"]


def test_chunked_fallback_non_attention_arch():
    """Recurrent/SSD stacks admit with one whole-prompt chunk (DESIGN.md §4
    fallback) instead of failing."""
    run = get_config("mamba2-130m").smoke()
    m = build_model(run)
    assert not m.supports_chunked_prefill()
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    prompts = _prompts(run, n=2, seed=9)
    _, outs = _serve(m, params, sw, prompts, max_new=3, strategy="specee",
                     cache="paged", prefill_chunk=4)
    assert all(len(o) == 3 for o in outs)


# ---------------- config validation ----------------
def test_serve_config_page_size_validation():
    with pytest.raises(ValueError, match="page_size must be > 0"):
        ServeConfig(page_size=0)
    with pytest.raises(ValueError, match="must divide"):
        ServeConfig(max_seq_len=1000, page_size=128)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeConfig(prefill_chunk=-1)
    # the smoke combination (16 / 128) is the CI-exercised one
    smoke = get_config("llama2-7b").smoke().serve
    assert smoke.page_size == 16 and smoke.max_seq_len == 128
    assert smoke.max_seq_len % smoke.page_size == 0


def test_cache_spec_resolution(setup):
    run, m, params, sw = setup
    assert CacheSpec.resolve(None, run.serve).kind == "dense"
    spec = CacheSpec.resolve("paged", run.serve)
    assert spec.kind == "paged" and spec.page_size == run.serve.page_size
    assert CacheSpec.resolve(spec, run.serve) is spec
    with pytest.raises(ValueError, match="kind"):
        CacheSpec(kind="mmap")
    sess = Engine.create(m, params, sw).new_session(batch=2)
    assert isinstance(sess.cache_mgr, DenseKVCache)   # default unchanged


# ---------------- slot-math property test ----------------
def test_paged_indirection_roundtrip_property():
    """Property (hypothesis): for any page table that is a permutation
    assignment of distinct pages per row, scatter-through-table followed by
    gather-view reproduces the dense layout exactly, and per-position
    scatter/gather agree with direct indexing."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.core import paged as paged_lib

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def run(data):
        B = data.draw(st.integers(1, 3))
        P = data.draw(st.integers(1, 4))
        ps = data.draw(st.sampled_from([2, 4, 8]))
        extra = data.draw(st.integers(0, 3))
        NP = B * P + extra + 1
        seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        perm = rng.permutation(NP - 1)[:B * P].reshape(B, P)
        table = jnp.asarray(perm, jnp.int32)
        dense = rng.standard_normal((B, P * ps, 3)).astype(np.float32)
        pool = jnp.zeros((NP, ps, 3), jnp.float32)
        # slab-scatter the whole dense layout, then gather it back
        pos = jnp.broadcast_to(jnp.arange(P * ps)[None], (B, P * ps))
        pool = paged_lib.scatter_slab(pool, table, pos, jnp.asarray(dense))
        view = paged_lib.gather_view(pool, table)
        np.testing.assert_array_equal(np.asarray(view), dense)
        # token-scatter at arbitrary per-row positions == dense row write
        wpos = jnp.asarray(rng.integers(0, P * ps, B), jnp.int32)
        vals = rng.standard_normal((B, 3)).astype(np.float32)
        pool2 = paged_lib.scatter_token(pool, table, wpos, jnp.asarray(vals))
        dense2 = dense.copy()
        dense2[np.arange(B), np.asarray(wpos)] = vals
        np.testing.assert_array_equal(
            np.asarray(paged_lib.gather_view(pool2, table)), dense2)
        got = paged_lib.gather_positions(pool2, table, wpos)
        np.testing.assert_array_equal(np.asarray(got), vals)

    run()


# ---------------- paged decode kernel ----------------
def test_paged_decode_kernel_matches_ref():
    """Page-table-aware split-KV kernel (interpret mode) vs the
    gather-then-dense-reference oracle, shuffled table + ragged lengths."""
    from repro.kernels.decode_attention import ops as da_ops
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    B, S, KVH, H, hd, ps = 3, 64, 2, 4, 32, 16
    NP = B * (S // ps) + 1
    kp = jax.random.normal(jax.random.PRNGKey(0), (NP, ps, KVH, hd))
    vp = jax.random.normal(jax.random.PRNGKey(1), (NP, ps, KVH, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    table = jax.random.permutation(
        jax.random.PRNGKey(3), NP - 1)[:B * (S // ps)].reshape(B, S // ps)
    clen = jnp.array([5, 33, 64], jnp.int32)
    for window in (None, 20):
        out = da_ops.paged_decode_attention(None, q, kp, vp, table, clen,
                                            window=window)
        ref = paged_decode_attention_ref(q, kp, vp, table, clen,
                                         window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, rtol=2e-6)


def test_paged_decode_kernel_end_to_end(setup):
    """decode_kernel + paged cache serves through the page-table kernel
    (shape/flow check; numerics covered by the ref parity above)."""
    run, m, params, sw = setup
    mk = build_model(run, ModelFlags(decode_kernel=True))
    prompts = _prompts(run, n=2, seed=10)
    _, outs = _serve(mk, params, sw, prompts, max_new=3, strategy="specee",
                     cache="paged")
    assert all(len(o) == 3 for o in outs)


def test_paged_decode_kernel_kv_quant_matches_xla(setup):
    """kv_quant no longer forces the gathered-XLA fallback: the paged kernel
    consumes the int8 pools + scale pools directly (same page-table gather,
    dequant inside the tile) and reproduces the XLA kv_quant path
    token-for-token."""
    run, m, params, sw = setup
    prompts = _prompts(run, n=2, seed=12)
    outs = {}
    for decode_kernel in (False, True):
        mq = build_model(run, ModelFlags(kv_quant=True,
                                         decode_kernel=decode_kernel))
        _, outs[decode_kernel] = _serve(mq, params, sw, prompts, max_new=4,
                                        strategy="specee", cache="paged")
    assert outs[True] == outs[False]
