"""Config registry, parameter counts, and shape-cell applicability."""
import pytest

from repro.config import applicable_shapes, shape_by_name, SHAPES
from repro.configs import ARCHS, get_config

ASSIGNED = [a for a in ARCHS if not a.startswith("llama2")]


def test_all_archs_load():
    for a in ARCHS:
        run = get_config(a)
        assert run.model.name == a
        assert run.model.num_layers == len(run.model.blocks())


@pytest.mark.parametrize("arch,lo,hi", [
    ("dbrx-132b", 125e9, 140e9),
    ("qwen3-moe-235b-a22b", 220e9, 245e9),
    ("deepseek-7b", 6.5e9, 7.5e9),
    ("minicpm-2b", 2.3e9, 3.0e9),
    ("command-r-plus-104b", 100e9, 112e9),
    ("starcoder2-15b", 14e9, 17e9),
    ("internvl2-26b", 18e9, 22e9),   # LM backbone only (vision is a stub)
    ("hubert-xlarge", 0.9e9, 1.1e9),
    ("recurrentgemma-9b", 8.5e9, 11.5e9),
    ("mamba2-130m", 0.11e9, 0.15e9),
    ("llama2-7b", 6.5e9, 7.0e9),
    ("llama2-70b", 65e9, 72e9),
])
def test_param_counts(arch, lo, hi):
    n = get_config(arch).model.param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    m = get_config("qwen3-moe-235b-a22b").model
    # A22B: ~22B active
    assert 15e9 <= m.active_param_count() <= 26e9
    d = get_config("dbrx-132b").model
    assert 30e9 <= d.active_param_count() <= 45e9


def test_shape_skips():
    # encoder-only: no decode shapes
    hub = get_config("hubert-xlarge").model
    names = [s.name for s in applicable_shapes(hub)]
    assert names == ["train_4k", "prefill_32k"]
    # full attention: no long_500k
    for a in ("deepseek-7b", "dbrx-132b", "command-r-plus-104b"):
        names = [s.name for s in applicable_shapes(get_config(a).model)]
        assert "long_500k" not in names
        assert "decode_32k" in names
    # sub-quadratic: long_500k runs
    for a in ("mamba2-130m", "recurrentgemma-9b"):
        names = [s.name for s in applicable_shapes(get_config(a).model)]
        assert "long_500k" in names


def test_total_cell_count():
    cells = sum(len(applicable_shapes(get_config(a).model)) for a in ASSIGNED)
    assert cells == 31  # 10 train + 10 prefill + 9 decode + 2 long (DESIGN §4)


def test_smoke_reduction():
    for a in ARCHS:
        sm = get_config(a).smoke().model
        assert sm.param_count() < 5e6
        assert sm.d_model == 128
        # family preserved
        assert sm.family == get_config(a).model.family


def test_shape_lookup():
    assert shape_by_name("train_4k").global_batch == 256
    assert shape_by_name("long_500k").seq_len == 524288
    with pytest.raises(KeyError):
        shape_by_name("nope")
