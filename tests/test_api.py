"""Unified decode API tests: Engine/DecodeSession/StepResult across all
three strategies, session-level cross-mode parity, serving over the session
(incl. tree-mode serving), and PRNG-seed threading under sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DenseStrategy, Engine, SpecEEStrategy, StepResult,
                       TreeStrategy, get_strategy)
from repro.configs import get_config
from repro.core import engine as eng
from repro.core.tree import TreeSpec
from repro.models.model import ModelFlags, build_model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    run = get_config("llama2-7b").smoke()
    m = build_model(run)
    params = m.init(jax.random.PRNGKey(0))
    sw = eng.init_specee(m, jax.random.PRNGKey(1))
    return run, m, params, sw


def _drain(session, first_res):
    """Collect per-row token lists until every row is done."""
    toks = [first_res.row_tokens(b) for b in range(first_res.batch)]
    while not session.all_done():
        res = session.step()
        for b in range(res.batch):
            toks[b].extend(res.row_tokens(b))
    return toks


def _prompts(run, B=2, T=8, seed=4):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, T), 0,
                              run.model.vocab_size)


# ---------------- strategy resolution ----------------
def test_get_strategy():
    assert isinstance(get_strategy("dense"), DenseStrategy)
    assert isinstance(get_strategy("specee"), SpecEEStrategy)
    assert isinstance(get_strategy("ar"), SpecEEStrategy)
    assert isinstance(get_strategy("tree"), TreeStrategy)
    s = TreeStrategy(threshold=0.3)
    assert get_strategy(s) is s
    with pytest.raises(ValueError):
        get_strategy("nope")


def test_strategy_validation(setup):
    run, m, params, sw = setup
    with pytest.raises(ValueError):
        Engine.create(m, params, sw=None, strategy="specee")
    run_ssm = get_config("mamba2-130m").smoke()
    m_ssm = build_model(run_ssm)
    with pytest.raises(ValueError):
        Engine.create(m_ssm, m_ssm.init(jax.random.PRNGKey(0)),
                      sw=eng.init_specee(m_ssm, jax.random.PRNGKey(1)),
                      strategy="tree")


# ---------------- session-level cross-mode parity ----------------
def test_session_specee_no_exit_matches_dense(setup):
    """Through the API: SpecEEStrategy with threshold > 1 emits tokens
    bit-identical to DenseStrategy (the merged-mapping invariant, now a
    property of the public surface)."""
    run, m, params, sw = setup
    prompts = _prompts(run)
    outs = {}
    for name, strat in [("dense", DenseStrategy()),
                        ("specee", SpecEEStrategy(threshold=1.5))]:
        session = Engine.create(m, params, sw, strategy=strat).new_session()
        res = session.prefill(prompts, max_new_tokens=6)
        outs[name] = _drain(session, res)
    assert outs["dense"] == outs["specee"]
    assert all(len(t) == 6 for t in outs["dense"])


def test_session_tree_no_exit_matches_dense(setup):
    """Tree strategy with exits disabled greedy-matches dense through the
    session (ragged multi-token emits reassemble to the same stream)."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=5)
    session = Engine.create(m, params, sw, strategy="dense").new_session()
    dense = _drain(session, session.prefill(prompts, max_new_tokens=9))
    tree = TreeStrategy(tree=TreeSpec(depth=2, branch=3), threshold=1.5)
    session = Engine.create(m, params, sw, strategy=tree).new_session()
    got = _drain(session, session.prefill(prompts, max_new_tokens=9))
    assert got == dense
    assert all(len(t) == 9 for t in got)


def test_session_dense_matches_legacy_decode(setup):
    """DenseStrategy (streamed emit) == model.decode_step + argmax (the
    historical materialized path), so folding verify_argmax into the dense
    emit changed nothing."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=6)
    T, G = prompts.shape[1], 5
    logits, cache, _ = m.prefill(params, {"tokens": prompts}, max_seq=T + G + 2)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    ref = [[int(t)] for t in tok]
    for _ in range(G):
        logits, cache = m.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for b in range(tok.shape[0]):
            ref[b].append(int(tok[b]))
    session = Engine.create(m, params, sw, strategy="dense").new_session()
    got = _drain(session, session.prefill(prompts, max_new_tokens=G + 1))
    assert got == ref


def test_step_result_shape_contract(setup):
    """Every strategy's StepResult is (B, W)-fixed-width with valid counts."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=7)
    for strat, width in [(DenseStrategy(), 1), (SpecEEStrategy(), 1),
                         (TreeStrategy(tree=TreeSpec(depth=2, branch=3)), 3)]:
        e = Engine.create(m, params, sw, strategy=strat)
        assert e.emit_width == width
        session = e.new_session()
        res = session.prefill(prompts, max_new_tokens=4)
        assert isinstance(res, StepResult)
        assert res.tokens.shape == (2, width)
        res = session.step()
        assert res.tokens.shape == (2, width)
        assert res.counts.shape == (2,) and res.done.shape == (2,)
        assert res.exit_layer.shape == (2,) and res.accept_len.shape == (2,)
        assert (res.counts >= 0).all() and (res.counts <= width).all()


def test_session_eos_and_budget(setup):
    """EOS mid-emit truncates; budget caps multi-token tree emits exactly."""
    run, m, params, sw = setup
    prompts = _prompts(run, seed=8)
    tree = TreeStrategy(tree=TreeSpec(depth=2, branch=3))
    session = Engine.create(m, params, sw, strategy=tree).new_session()
    ref = _drain(session, session.prefill(prompts, max_new_tokens=10))
    eos = ref[0][4]
    session = Engine.create(m, params, sw, strategy=tree).new_session()
    got = _drain(session, session.prefill(prompts, max_new_tokens=10,
                                          eos_token=eos))
    assert got[0] == ref[0][:ref[0].index(eos) + 1]
    assert len(got[1]) <= 10


# ---------------- serving over the session ----------------
def _serve_prompts(run, n=3, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, run.model.vocab_size, int(rng.integers(4, 10)))
            for _ in range(n)]


def test_serving_tree_mode_smoke(setup):
    """Tree-mode serving (previously impossible): submit → run_to_completion
    emits exactly the budget for every request, multi-token ticks included."""
    run, m, params, sw = setup
    se = ServingEngine(m, params, sw, strategy="tree")
    reqs = [se.submit(p, max_new_tokens=7)
            for p in _serve_prompts(run)]
    done = se.run_to_completion()
    assert len(done) == len(reqs)
    for r in reqs:
        assert r.done and len(r.output) == 7
        assert len(r.accept_lens) == len(r.exit_points)
    # tree ticks emit ≥1 token each → fewer ticks than tokens is possible;
    # every tick's emit is bounded by depth+1
    for r in reqs:
        assert len(r.exit_points) <= 6


def test_serving_specee_matches_dense_greedy(setup):
    """Serving cross-mode parity: untrained predictors never verify an exit
    falsely — specee serving == dense serving token-for-token is NOT
    guaranteed in general, but with threshold>1 strategies it is."""
    run, m, params, sw = setup
    outs = {}
    for key, strat in [("dense", "dense"),
                       ("specee", SpecEEStrategy(threshold=1.5))]:
        se = ServingEngine(m, params, sw, strategy=strat)
        reqs = [se.submit(p, max_new_tokens=6)
                for p in _serve_prompts(run, seed=1)]
        se.run_to_completion()
        outs[key] = [r.output for r in reqs]
    assert outs["dense"] == outs["specee"]


def test_serving_fused_gate_default_on(setup):
    """Serve-path adoption: the serving engine flips exit_gate_kernel on by
    default (and honors fused_gate=False)."""
    run, m, params, sw = setup
    assert not getattr(m.flags, "exit_gate_kernel", False)
    se = ServingEngine(m, params, sw, strategy="specee")
    assert se.model.flags.exit_gate_kernel
    se_ref = ServingEngine(m, params, sw, strategy="specee", fused_gate=False)
    assert not se_ref.model.flags.exit_gate_kernel
    # and the fused path serves identical greedy tokens (CPU: fused-XLA gate)
    outs = []
    for engine in (se, se_ref):
        reqs = [engine.submit(p, max_new_tokens=5)
                for p in _serve_prompts(run, seed=2)]
        engine.run_to_completion()
        outs.append([r.output for r in reqs])
    assert outs[0] == outs[1]


def test_serving_prng_seed_threads_through(setup):
    """Regression (prng_seed was silently ignored): two seeds must diverge
    under sampling; the same seed must reproduce."""
    run, m, params, sw = setup
    prompt = _serve_prompts(run, n=1, seed=3)[0]

    def sample_run(seed):
        se = ServingEngine(m, params, sw,
                           strategy=DenseStrategy(temperature=1.0),
                           prng_seed=seed)
        r = se.submit(prompt, max_new_tokens=12)
        se.run_to_completion()
        return r.output

    a0, a1, a0_again = sample_run(0), sample_run(1), sample_run(0)
    assert a0 != a1, "different seeds produced identical samples"
    assert a0 == a0_again, "same seed not reproducible"


def test_serving_greedy_ignores_seed(setup):
    """Greedy serving is seed-invariant (sanity check on the sampling test)."""
    run, m, params, sw = setup
    prompt = _serve_prompts(run, n=1, seed=5)[0]
    outs = []
    for seed in (0, 1):
        se = ServingEngine(m, params, sw, strategy="dense", prng_seed=seed)
        r = se.submit(prompt, max_new_tokens=6)
        se.run_to_completion()
        outs.append(r.output)
    assert outs[0] == outs[1]


def test_serving_continuous_batching_overflow(setup):
    """More requests than slots: pending queue drains as slots free."""
    run, m, params, sw = setup
    B = run.serve.max_batch
    se = ServingEngine(m, params, sw, strategy="specee")
    reqs = [se.submit(p, max_new_tokens=4)
            for p in _serve_prompts(run, n=2 * B + 1, seed=6)]
    done = se.run_to_completion()
    assert len(done) == 2 * B + 1
    assert all(len(r.output) == 4 for r in reqs)
